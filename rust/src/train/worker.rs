//! Worker thread: one per processing node of Fig. 2.
//!
//! Per round: draw the local data shard, compute the stochastic gradient
//! through the compute service (the AOT model artifact), quantize + encode
//! it with this worker's scheme and shared-seed dither stream, and send the
//! wire message to the server. The worker never sees other workers' data.

use std::sync::mpsc;
use std::sync::Arc;

use crate::data::{Batch, ImageDataset, TokenDataset};
use crate::prng::DitherStream;
use crate::quant::{EfState, GradQuantizer, PayloadCodec, Scheme};
use crate::runtime::ComputeHandle;

// The message type lives with the rest of the exchange machinery in
// `comm`; re-exported here because workers are its producers.
pub use crate::comm::{RoundSpec, WorkerMsg};

/// Commands from the server/trainer to a worker.
pub enum WorkerCmd {
    /// Run round `round` against the given (logically replicated) params,
    /// encoding under `spec` — the per-round negotiation the leader's
    /// level policy planned. Workers re-key their quantizer only when the
    /// spec actually changes, so fixed-policy runs pay nothing.
    Round {
        round: u64,
        params: Arc<Vec<f32>>,
        spec: RoundSpec,
    },
    Shutdown,
}

/// The task a worker computes gradients for.
#[derive(Clone)]
pub enum TaskData {
    Image {
        model: String,
        ds: ImageDataset,
        feat: usize,
    },
    Lm {
        model: String,
        ds: TokenDataset,
        seq: usize,
    },
}

pub struct WorkerCfg {
    pub id: usize,
    pub workers: usize,
    pub per_worker_batch: usize,
    pub scheme: Scheme,
    pub run_seed: u64,
    /// Wire-v2 framing: split the flat gradient into this many per-tensor
    /// frames per message (1 = single-frame, the classic layout).
    pub tensor_frames: usize,
    /// Wire-v3 index-lane codec at setup; each round's actual codec rides
    /// in the round command's [`RoundSpec`].
    pub codec: PayloadCodec,
    /// Error feedback: own an [`EfState`] lane set and feed
    /// `v = g + residual` into every encode. The trainer validates scheme
    /// support before spawning workers.
    pub error_feedback: bool,
    pub task: TaskData,
}

/// A running worker: command channel + join handle.
pub struct Worker {
    pub id: usize,
    pub cmd: mpsc::Sender<WorkerCmd>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Worker {
    pub fn spawn_pair(
        cfg: WorkerCfg,
        compute: ComputeHandle,
        out: mpsc::Sender<crate::Result<WorkerMsg>>,
    ) -> crate::Result<Worker> {
        let (cmd_tx, cmd_rx) = mpsc::channel::<WorkerCmd>();
        let id = cfg.id;
        let join = std::thread::Builder::new()
            .name(format!("ndq-worker-{id}"))
            .spawn(move || worker_loop(cfg, compute, cmd_rx, out))?;
        Ok(Worker {
            id,
            cmd: cmd_tx,
            join: Some(join),
        })
    }

    pub fn shutdown(&mut self) {
        let _ = self.cmd.send(WorkerCmd::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(
    cfg: WorkerCfg,
    compute: ComputeHandle,
    cmd_rx: mpsc::Receiver<WorkerCmd>,
    out: mpsc::Sender<crate::Result<WorkerMsg>>,
) {
    // encoder state for the currently-negotiated scheme; re-built only
    // when a round command carries a different spec (the per-round levels
    // dial). The dither stream is keyed (seed, worker) — scheme-free — so
    // it survives every re-negotiation, as Alg. 1 requires. The EF lanes
    // likewise live OUTSIDE the quantizer: residuals are kept in gradient
    // units, so a re-leveled rebuild carries them through unchanged.
    let mut scheme = cfg.scheme;
    let mut quantizer = scheme.build();
    let mut ef = cfg.error_feedback.then(EfState::new);
    let dither = DitherStream::new(cfg.run_seed, cfg.id as u32);
    while let Ok(cmd) = cmd_rx.recv() {
        match cmd {
            WorkerCmd::Shutdown => break,
            WorkerCmd::Round { round, params, spec } => {
                let want = spec.worker_scheme(cfg.id, cfg.workers);
                if want != scheme {
                    scheme = want;
                    quantizer = scheme.build();
                }
                let res = run_round(
                    &cfg,
                    &compute,
                    quantizer.as_mut(),
                    ef.as_mut(),
                    &dither,
                    round,
                    &params,
                    spec.codec,
                );
                // Drop our params reference BEFORE sending the result: the
                // mpsc send synchronizes-with the leader's recv, so once the
                // leader has all P messages every worker clone is gone and
                // the leader can mutate the replicated params in place.
                drop(params);
                if out.send(res).is_err() {
                    break; // server gone
                }
            }
        }
    }
}

fn run_round(
    cfg: &WorkerCfg,
    compute: &ComputeHandle,
    quantizer: &mut dyn GradQuantizer,
    ef: Option<&mut EfState>,
    dither: &DitherStream,
    round: u64,
    params: &Arc<Vec<f32>>,
    codec: PayloadCodec,
) -> crate::Result<WorkerMsg> {
    let b = cfg.per_worker_batch;
    let (loss, grad) = match &cfg.task {
        TaskData::Image { model, ds, feat } => {
            let mut batch = Batch::new(b, *feat);
            ds.train_batch(round, cfg.id, cfg.workers, b, &mut batch);
            compute.grad_image(model, params, batch.x, batch.y, b)?
        }
        TaskData::Lm { model, ds, seq } => {
            let mut tokens = vec![0i32; b * seq];
            ds.train_batch(round, cfg.id, cfg.workers, b, *seq, &mut tokens);
            compute.grad_lm(model, params, tokens, b)?
        }
    };
    let slices = crate::quant::frame_slices(&grad, cfg.tensor_frames);
    let wire = match ef {
        Some(ef) => ef.encode_tensors(quantizer, &slices, &mut dither.round(round), codec)?,
        None => quantizer.encode_tensors_coded(&slices, &mut dither.round(round), codec),
    };
    Ok(WorkerMsg::new(cfg.id, round, loss, wire))
}
