//! Small utilities: JSON (manifest I/O), binary file helpers, timing.

pub mod json;

use std::io::Read;
use std::path::Path;

/// Read a little-endian f32 binary file (the `*_init.bin` artifacts).
pub fn read_f32_bin(path: &Path) -> crate::Result<Vec<f32>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    anyhow::ensure!(
        bytes.len() % 4 == 0,
        "{}: length {} not a multiple of 4",
        path.display(),
        bytes.len()
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a little-endian f32 binary file.
pub fn write_f32_bin(path: &Path, data: &[f32]) -> crate::Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes)?;
    Ok(())
}

/// Wall-clock stopwatch helper.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    // ndq-lint: allow(wall-clock) the Stopwatch type IS the sanctioned wall timer; used for progress lines only
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_bin_roundtrip() {
        let dir = std::env::temp_dir().join("ndq_util_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        let data = vec![1.5f32, -2.25, 0.0, f32::MAX];
        write_f32_bin(&p, &data).unwrap();
        assert_eq!(read_f32_bin(&p).unwrap(), data);
    }
}
