//! `ndq lint` — repo-invariant static analysis.
//!
//! The statistical claims this repo reproduces (DQSG/NDQSG ≡ unquantized
//! SG + independent bounded noise) are only testable because every run is
//! a pure function of its seed. That purity rests on conventions that have
//! already been broken once each: no wall clocks in billed paths, canonical
//! fold order, panic-free decoding of hostile wire bytes, allocation-free
//! `*_into` decoders, and no unchecked narrowing on wire lengths. This
//! module makes those conventions machine-checked.
//!
//! Architecture (bottom-up):
//!
//! * [`lexer`] — a lightweight Rust tokenizer that strips comments and
//!   string literals, so rules match code, not prose;
//! * [`rules`] — the rule registry: each rule is a token-level checker
//!   plus a module scope (`src/…` path prefixes) tying it to the code
//!   where its contract is load-bearing;
//! * [`engine`] — per-file driver: elides `#[cfg(test)]`/`#[test]` code,
//!   tracks `fn` spans (rules and allows can be function-scoped), resolves
//!   `// ndq-lint: allow(<rule>) <reason>` annotations (reasons are
//!   mandatory, stale allows are themselves diagnostics), and walks path
//!   sets deterministically.
//!
//! The pass is wired as a hard tier-1 gate: `ndq lint src` must exit 0
//! (see `scripts/tier1.sh` and the GitHub workflow), and
//! `tests/lint_engine.rs` pins both the engine semantics (via seeded
//! fixtures under `tests/lint_fixtures/`) and the repo-clean invariant.

pub mod engine;
pub mod lexer;
pub mod rules;

pub use engine::{lint_paths, lint_source, Diagnostic, LintReport};
pub use rules::{Rule, RULES};
