//! A lightweight Rust lexer for the lint pass.
//!
//! This is not a full grammar — it is the minimal tokenizer the rule
//! engine needs to reason about source *mechanically* without being fooled
//! by surface syntax:
//!
//! * comments (line, nested block) are stripped, but line comments are
//!   kept aside so the engine can parse `ndq-lint:` directives out of them;
//! * string/char literals are reduced to opaque tokens, so a rule matching
//!   the identifier `unwrap` can never fire on the *string* `"unwrap"`;
//! * raw strings (`r"…"`, `r#"…"#`), byte strings and raw identifiers are
//!   handled, and lifetimes are distinguished from char literals;
//! * every token carries its 1-based source line for diagnostics.
//!
//! The lexer is intentionally forgiving: on malformed input it degrades to
//! per-character punctuation tokens rather than erroring, because the lint
//! pass must never be the thing that crashes on a weird-but-compiling file
//! (rustc is the authority on what parses; we only classify).

/// Token classes the rule engine distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `unwrap`, `Instant`, …).
    Ident,
    /// Lifetime (`'a`, `'static`) — kept distinct so char-literal logic
    /// cannot swallow generic code.
    Lifetime,
    /// Numeric literal (`42`, `1.0e-3`, `0xff`).
    Num,
    /// String literal of any flavor; the content is discarded.
    Str,
    /// Char or byte literal; the content is discarded.
    Char,
    /// Punctuation, one or two characters (`::`, `==`, `[`, `!`, …).
    Punct,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    /// Whether this token is the identifier/keyword `s`.
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    /// Whether this token is the punctuation `s`.
    pub fn is_punct(&self, s: &str) -> bool {
        self.kind == TokKind::Punct && self.text == s
    }
}

/// A `//` comment body (text after the slashes) with its line — the only
/// channel `ndq-lint:` directives travel on.
#[derive(Debug, Clone)]
pub struct LineComment {
    pub line: u32,
    pub text: String,
}

/// Lexer output: the significant-token stream plus all line comments.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<LineComment>,
}

/// Two-character punctuation sequences lexed as single tokens. Order is
/// irrelevant (all are length 2); three-character operators (`..=`, `<<=`)
/// lex as a pair + singleton, which no rule currently cares about.
const PUNCT2: &[&str] = &[
    "::", "==", "!=", "<=", ">=", "&&", "||", "->", "=>", "..", "+=", "-=", "*=", "/=", "%=",
    "^=", "&=", "|=", "<<", ">>",
];

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src`. Never fails; see module docs for the degradation
/// contract on malformed input.
pub fn lex(src: &str) -> Lexed {
    let c: Vec<char> = src.chars().collect();
    let n = c.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let ch = c[i];
        if ch == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if ch.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment — captured for directive parsing, then dropped
        if ch == '/' && i + 1 < n && c[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && c[j] != '\n' {
                j += 1;
            }
            out.comments.push(LineComment {
                line,
                text: c[start..j].iter().collect(),
            });
            i = j;
            continue;
        }
        // block comment, nested per Rust
        if ch == '/' && i + 1 < n && c[i + 1] == '*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if c[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if c[j] == '/' && j + 1 < n && c[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if c[j] == '*' && j + 1 < n && c[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // plain string literal
        if ch == '"' {
            i = skip_quoted(&c, i, &mut line);
            out.toks.push(Tok {
                kind: TokKind::Str,
                text: String::new(),
                line,
            });
            continue;
        }
        // lifetime vs char literal
        if ch == '\'' {
            let (j, kind) = skip_char_or_lifetime(&c, i, &mut line);
            let text = if kind == TokKind::Lifetime {
                c[i + 1..j].iter().collect()
            } else {
                String::new()
            };
            out.toks.push(Tok { kind, text, line });
            i = j;
            continue;
        }
        // identifier / keyword — including r"…" / b"…" / br#"…"# string
        // prefixes and r#raw identifiers
        if is_ident_start(ch) {
            let mut j = i + 1;
            while j < n && is_ident_continue(c[j]) {
                j += 1;
            }
            let word: String = c[i..j].iter().collect();
            let next = if j < n { Some(c[j]) } else { None };
            let raw_capable = word == "r" || word == "br";
            if raw_capable && (next == Some('"') || next == Some('#')) {
                if let Some(end) = skip_raw_string(&c, j, &mut line) {
                    out.toks.push(Tok {
                        kind: TokKind::Str,
                        text: String::new(),
                        line,
                    });
                    i = end;
                    continue;
                }
                // `r#ident` raw identifier: re-lex the ident after the hash
                if next == Some('#') && j + 1 < n && is_ident_start(c[j + 1]) {
                    let mut k = j + 1;
                    while k < n && is_ident_continue(c[k]) {
                        k += 1;
                    }
                    out.toks.push(Tok {
                        kind: TokKind::Ident,
                        text: c[j + 1..k].iter().collect(),
                        line,
                    });
                    i = k;
                    continue;
                }
            }
            if word == "b" && next == Some('"') {
                i = skip_quoted(&c, j, &mut line);
                out.toks.push(Tok {
                    kind: TokKind::Str,
                    text: String::new(),
                    line,
                });
                continue;
            }
            if word == "b" && next == Some('\'') {
                let (end, _) = skip_char_or_lifetime(&c, j, &mut line);
                out.toks.push(Tok {
                    kind: TokKind::Char,
                    text: String::new(),
                    line,
                });
                i = end;
                continue;
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text: word,
                line,
            });
            i = j;
            continue;
        }
        // numeric literal (int, float, hex/oct/bin); `0..n` keeps the dots
        if ch.is_ascii_digit() {
            let mut j = i + 1;
            let mut seen_dot = false;
            while j < n {
                let d = c[j];
                if d.is_ascii_alphanumeric() || d == '_' {
                    j += 1;
                } else if d == '.' && !seen_dot && j + 1 < n && c[j + 1].is_ascii_digit() {
                    seen_dot = true;
                    j += 1;
                } else if (d == '+' || d == '-')
                    && seen_dot
                    && (c[j - 1] == 'e' || c[j - 1] == 'E')
                {
                    j += 1;
                } else {
                    break;
                }
            }
            out.toks.push(Tok {
                kind: TokKind::Num,
                text: c[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        // punctuation: greedy two-char, else one char
        if i + 1 < n {
            let two: String = c[i..i + 2].iter().collect();
            if PUNCT2.contains(&two.as_str()) {
                out.toks.push(Tok {
                    kind: TokKind::Punct,
                    text: two,
                    line,
                });
                i += 2;
                continue;
            }
        }
        out.toks.push(Tok {
            kind: TokKind::Punct,
            text: ch.to_string(),
            line,
        });
        i += 1;
    }
    out
}

/// Skip a `"…"` literal starting at the opening quote; returns the index
/// past the closing quote. Handles escapes and multi-line strings.
fn skip_quoted(c: &[char], open: usize, line: &mut u32) -> usize {
    let n = c.len();
    let mut j = open + 1;
    while j < n {
        match c[j] {
            '\\' => {
                // an escaped newline (string continuation) still ends a
                // source line — without this the whole rest of the file
                // reports off-by-N diagnostics
                if j + 1 < n && c[j + 1] == '\n' {
                    *line += 1;
                }
                j += 2;
            }
            '\n' => {
                *line += 1;
                j += 1;
            }
            '"' => return j + 1,
            _ => j += 1,
        }
    }
    n
}

/// Skip a raw string whose hashes/quote begin at `at` (just past the `r` /
/// `br` prefix). Returns `None` if this is not actually a raw string
/// opening (e.g. `r#match`).
fn skip_raw_string(c: &[char], at: usize, line: &mut u32) -> Option<usize> {
    let n = c.len();
    let mut hashes = 0usize;
    let mut j = at;
    while j < n && c[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || c[j] != '"' {
        return None;
    }
    j += 1;
    while j < n {
        if c[j] == '\n' {
            *line += 1;
            j += 1;
            continue;
        }
        if c[j] == '"' {
            let mut k = 0usize;
            while k < hashes && j + 1 + k < n && c[j + 1 + k] == '#' {
                k += 1;
            }
            if k == hashes {
                return Some(j + 1 + hashes);
            }
        }
        j += 1;
    }
    Some(n)
}

/// Disambiguate `'…` into a lifetime or a char literal starting at the
/// quote; returns (index past the token, kind).
fn skip_char_or_lifetime(c: &[char], open: usize, line: &mut u32) -> (usize, TokKind) {
    let n = c.len();
    // escape ⇒ definitely a char literal
    if open + 1 < n && c[open + 1] == '\\' {
        let mut j = open + 2;
        while j < n && c[j] != '\'' {
            j += 1;
        }
        return ((j + 1).min(n), TokKind::Char);
    }
    // `'a'` is a char; `'a` followed by anything else is a lifetime
    if open + 1 < n && is_ident_start(c[open + 1]) {
        let mut j = open + 2;
        while j < n && is_ident_continue(c[j]) {
            j += 1;
        }
        if j < n && c[j] == '\'' && j == open + 2 {
            return (j + 1, TokKind::Char);
        }
        return (j, TokKind::Lifetime);
    }
    // non-identifier char literal: `'$'`, `' '`, …
    let mut j = open + 1;
    if j < n && c[j] == '\n' {
        *line += 1;
    }
    if j < n {
        j += 1;
    }
    if j < n && c[j] == '\'' {
        j += 1;
    }
    (j, TokKind::Char)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn strings_and_comments_are_opaque() {
        let lx = lex("let s = \"Instant::now()\"; // Instant::now\n/* SystemTime::now */ x");
        assert!(!lx.toks.iter().any(|t| t.text.contains("Instant")));
        assert!(!lx.toks.iter().any(|t| t.text.contains("SystemTime")));
        assert_eq!(lx.comments.len(), 1);
        assert!(lx.comments[0].text.contains("Instant::now"));
    }

    #[test]
    fn raw_strings_and_raw_idents() {
        assert_eq!(texts("r#\"unwrap\"# r\"x\" br#\"y\"#"), vec!["", "", ""]);
        assert_eq!(texts("r#match x"), vec!["match", "x"]);
    }

    #[test]
    fn lifetimes_vs_chars() {
        let lx = lex("fn f<'a>(x: &'a str) { let c = 'q'; let d = '\\n'; }");
        let lifetimes: Vec<_> = lx
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .collect();
        assert_eq!(lifetimes.len(), 2);
        let chars = lx.toks.iter().filter(|t| t.kind == TokKind::Char).count();
        assert_eq!(chars, 2);
    }

    #[test]
    fn numbers_and_ranges() {
        let lx = lex("for i in 0..n { let x = 1.0e-3; let y = 0xff; }");
        let nums: Vec<_> = lx
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text.clone())
            .collect();
        assert_eq!(nums, vec!["0", "1.0e-3", "0xff"]);
    }

    #[test]
    fn line_numbers_track_multiline_tokens() {
        let lx = lex("a\n\"two\nline\"\nb");
        let a = lx.toks.iter().find(|t| t.text == "a").unwrap();
        let b = lx.toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(a.line, 1);
        assert_eq!(b.line, 4);
    }

    #[test]
    fn escaped_newline_in_string_counts_lines() {
        // `\` + newline is a string continuation but still a source line
        let lx = lex("a\n\"one \\\ntwo\"\nb");
        let b = lx.toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b.line, 4);
    }

    #[test]
    fn two_char_punct() {
        let lx = lex("a == b != c :: d");
        let puncts: Vec<_> = lx
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Punct)
            .map(|t| t.text.clone())
            .collect();
        assert_eq!(puncts, vec!["==", "!=", "::"]);
    }
}
