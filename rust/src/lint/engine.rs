//! The per-file rule engine: directive parsing, test-code elision,
//! function-span tracking, allow resolution, and the path walker.
//!
//! # Annotation grammar
//!
//! Directives ride in `//` comments and start with `ndq-lint:`:
//!
//! * `// ndq-lint: allow(<rule>[, <rule>…]) <reason>` — suppress the named
//!   rule(s). The reason is **mandatory**; a reasonless allow is itself a
//!   diagnostic (`bad-allow`), as is naming an unknown rule. Placement:
//!   a trailing comment covers its own line; a comment on its own line
//!   covers the next code line; and when the covered line is a `fn`
//!   header, the allow covers that whole function body. An allow that
//!   suppresses nothing is a `unused-allow` diagnostic — stale escape
//!   hatches rot the audit.
//! * `// ndq-lint: as(<path>)` — scope this file as if it lived at
//!   `<path>` (e.g. `src/comm/net.rs`). Used by the lint fixtures under
//!   `tests/lint_fixtures/` to exercise module-scoped rules from outside
//!   the tree.
//!
//! # What is linted
//!
//! Rules see a token stream with comments/strings stripped (see
//! [`crate::lint::lexer`]) and with `#[cfg(test)]` items and `#[test]`
//! functions elided — test code may unwrap, allocate and read clocks
//! freely; the contracts apply to shipping code.

use std::fmt;
use std::path::{Path, PathBuf};

use crate::lint::lexer::{self, Tok, TokKind};
use crate::lint::rules;

/// One lint finding, printable as `path:line: rule: message`.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub path: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}: {}", self.path, self.line, self.rule, self.msg)
    }
}

/// A finding as emitted by a rule, before path/allow resolution.
#[derive(Debug)]
pub struct RawDiag {
    pub line: u32,
    pub msg: String,
}

/// Span of one `fn` item in the (test-stripped) token stream.
#[derive(Debug)]
pub struct FnSpan {
    pub name: String,
    /// Line of the `fn` keyword.
    pub header_line: u32,
    /// Token index of the body `{`.
    pub open_idx: usize,
    /// Token index one past the matching `}`.
    pub end_idx: usize,
    /// Line of the closing `}`.
    pub close_line: u32,
}

/// Everything a rule sees about one file.
pub struct FileCtx<'a> {
    /// Normalized module path (`src/comm/net.rs`), honoring `as(…)`.
    pub module_path: &'a str,
    /// Significant tokens, test code elided.
    pub toks: &'a [Tok],
    /// `fn` spans over `toks`, in source order.
    pub fns: &'a [FnSpan],
}

impl FileCtx<'_> {
    /// Innermost function containing token `idx`, if any.
    pub fn enclosing_fn(&self, idx: usize) -> Option<&FnSpan> {
        self.fns
            .iter()
            .filter(|f| f.open_idx < idx && idx + 1 < f.end_idx)
            .max_by_key(|f| f.open_idx)
    }
}

/// Rule name of the meta-diagnostic for malformed/unjustified directives.
pub const BAD_ALLOW: &str = "bad-allow";
/// Rule name of the meta-diagnostic for allows that suppressed nothing.
pub const UNUSED_ALLOW: &str = "unused-allow";

#[derive(Debug)]
enum Directive {
    Allow { rules: Vec<String>, reason: String },
    As(String),
}

/// Parse one line-comment body. `None` ⇒ not a lint directive at all;
/// `Some(Err(msg))` ⇒ malformed directive (reported as `bad-allow`).
fn parse_directive(text: &str) -> Option<Result<Directive, String>> {
    let rest = text.trim().strip_prefix("ndq-lint:")?.trim();
    if let Some(inner) = rest.strip_prefix("allow(") {
        let Some(close) = inner.find(')') else {
            return Some(Err("allow(…) is missing its closing parenthesis".into()));
        };
        let names: Vec<String> = inner[..close]
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        if names.is_empty() {
            return Some(Err("allow(…) names no rule".into()));
        }
        let reason = inner[close + 1..].trim().to_string();
        return Some(Ok(Directive::Allow { rules: names, reason }));
    }
    if let Some(inner) = rest.strip_prefix("as(") {
        let Some(close) = inner.find(')') else {
            return Some(Err("as(…) is missing its closing parenthesis".into()));
        };
        return Some(Ok(Directive::As(inner[..close].trim().to_string())));
    }
    Some(Err(format!("unrecognized ndq-lint directive `{rest}`")))
}

struct AllowEntry {
    line: u32,
    rules: Vec<String>,
    /// Inclusive line range this allow suppresses, resolved after lexing.
    covers: (u32, u32),
    used: bool,
}

/// Map `rust/src/comm/net.rs`-style paths onto the `src/…` module space
/// the rule scopes are written against (first `src` path component wins).
fn normalize_path(path: &str) -> String {
    let norm = path.replace('\\', "/");
    let comps: Vec<&str> = norm.split('/').collect();
    for (i, c) in comps.iter().enumerate() {
        if *c == "src" {
            return comps[i..].join("/");
        }
    }
    norm
}

/// Elide `#[cfg(test)]` items and `#[test]` functions from the stream:
/// the lint contracts bind shipping code, not its tests.
fn strip_test_code(toks: Vec<Tok>) -> Vec<Tok> {
    let n = toks.len();
    let mut drop = vec![false; n];
    let mut i = 0usize;
    while i < n {
        if !(toks[i].is_punct("#") && i + 1 < n && toks[i + 1].is_punct("[")) {
            i += 1;
            continue;
        }
        // consume a run of consecutive outer attributes
        let cluster_start = i;
        let mut is_test = false;
        let mut j = i;
        while j + 1 < n && toks[j].is_punct("#") && toks[j + 1].is_punct("[") {
            let mut depth = 0i32;
            let mut k = j + 1;
            let content_start = j + 2;
            while k < n {
                if toks[k].is_punct("[") {
                    depth += 1;
                } else if toks[k].is_punct("]") {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                k += 1;
            }
            let content = &toks[content_start..k.min(n)];
            if let Some(first) = content.first() {
                if first.is_ident("test") {
                    is_test = true;
                }
                if first.is_ident("cfg") && content.iter().any(|t| t.is_ident("test")) {
                    is_test = true;
                }
            }
            j = (k + 1).min(n);
        }
        if !is_test {
            i = j;
            continue;
        }
        // find the end of the attributed item: a `;` outside brackets, or
        // the matching `}` of its body
        let mut k = j;
        let mut pd = 0i32;
        let mut end = n - 1;
        while k < n {
            let t = &toks[k];
            if t.is_punct("(") || t.is_punct("[") {
                pd += 1;
            } else if t.is_punct(")") || t.is_punct("]") {
                pd -= 1;
            } else if pd == 0 && t.is_punct(";") {
                end = k;
                break;
            } else if pd == 0 && t.is_punct("{") {
                let mut bd = 1i32;
                let mut m = k + 1;
                while m < n && bd > 0 {
                    if toks[m].is_punct("{") {
                        bd += 1;
                    } else if toks[m].is_punct("}") {
                        bd -= 1;
                    }
                    m += 1;
                }
                end = m - 1;
                break;
            }
            k += 1;
        }
        for d in drop.iter_mut().take(end + 1).skip(cluster_start) {
            *d = true;
        }
        i = end + 1;
    }
    toks.into_iter()
        .zip(drop)
        .filter(|(_, d)| !d)
        .map(|(t, _)| t)
        .collect()
}

/// Locate every `fn` item body in the stream. Signatures track only
/// paren/bracket nesting — const-generic brace expressions in signatures
/// are not supported (and not used in this crate).
fn fn_spans(toks: &[Tok]) -> Vec<FnSpan> {
    let n = toks.len();
    let mut spans = Vec::new();
    for i in 0..n {
        if !toks[i].is_ident("fn") || i + 1 >= n || toks[i + 1].kind != TokKind::Ident {
            continue;
        }
        let mut k = i + 2;
        let mut pd = 0i32;
        while k < n {
            let t = &toks[k];
            if t.is_punct("(") || t.is_punct("[") {
                pd += 1;
            } else if t.is_punct(")") || t.is_punct("]") {
                pd -= 1;
            } else if pd == 0 && t.is_punct(";") {
                // bodyless trait/extern declaration
                break;
            } else if pd == 0 && t.is_punct("{") {
                let mut bd = 1i32;
                let mut m = k + 1;
                while m < n && bd > 0 {
                    if toks[m].is_punct("{") {
                        bd += 1;
                    } else if toks[m].is_punct("}") {
                        bd -= 1;
                    }
                    m += 1;
                }
                spans.push(FnSpan {
                    name: toks[i + 1].text.clone(),
                    header_line: toks[i].line,
                    open_idx: k,
                    end_idx: m,
                    close_line: toks[m - 1].line,
                });
                break;
            }
            k += 1;
        }
    }
    spans
}

/// Resolve which lines an allow at comment line `line` covers.
fn resolve_allow_cover(line: u32, toks: &[Tok], fns: &[FnSpan]) -> (u32, u32) {
    let target = if toks.iter().any(|t| t.line == line) {
        line
    } else {
        toks.iter()
            .map(|t| t.line)
            .filter(|&l| l > line)
            .min()
            .unwrap_or(line)
    };
    if let Some(f) = fns.iter().find(|f| f.header_line == target) {
        return (target, f.close_line);
    }
    // an allow above an attribute cluster (`#[inline]`, `#[derive(…)]`)
    // covers the attributed item: hop over the attributes and check
    // whether a `fn` header is what they decorate
    let n = toks.len();
    let Some(mut i) = toks.iter().position(|t| t.line == target) else {
        return (target, target);
    };
    while i + 1 < n && toks[i].is_punct("#") && toks[i + 1].is_punct("[") {
        let mut depth = 0i32;
        let mut k = i + 1;
        while k < n {
            if toks[k].is_punct("[") {
                depth += 1;
            } else if toks[k].is_punct("]") {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            k += 1;
        }
        i = (k + 1).min(n);
    }
    if i < n {
        if let Some(f) = fns.iter().find(|f| f.header_line == toks[i].line) {
            return (target, f.close_line);
        }
    }
    (target, target)
}

/// Lint one file's source. `path` is used for scoping (normalized onto
/// `src/…`, unless the file carries an `as(…)` directive) and echoed in
/// diagnostics verbatim.
pub fn lint_source(path: &str, src: &str) -> Vec<Diagnostic> {
    let lexed = lexer::lex(src);
    let mut diags: Vec<Diagnostic> = Vec::new();
    let mut allows: Vec<AllowEntry> = Vec::new();
    let mut as_path: Option<String> = None;

    let known: Vec<&str> = rules::RULES.iter().map(|r| r.name).collect();
    for cm in &lexed.comments {
        match parse_directive(&cm.text) {
            None => {}
            Some(Err(msg)) => diags.push(Diagnostic {
                path: path.to_string(),
                line: cm.line,
                rule: BAD_ALLOW,
                msg,
            }),
            Some(Ok(Directive::As(p))) => as_path = Some(p),
            Some(Ok(Directive::Allow { rules: names, reason })) => {
                let unknown: Vec<&String> =
                    names.iter().filter(|r| !known.contains(&r.as_str())).collect();
                if !unknown.is_empty() {
                    diags.push(Diagnostic {
                        path: path.to_string(),
                        line: cm.line,
                        rule: BAD_ALLOW,
                        msg: format!(
                            "allow names unknown rule(s) {:?} — see `ndq lint --rules`",
                            unknown
                        ),
                    });
                } else if reason.is_empty() {
                    diags.push(Diagnostic {
                        path: path.to_string(),
                        line: cm.line,
                        rule: BAD_ALLOW,
                        msg: format!(
                            "allow({}) has no reason — every suppression must say why \
                             the invariant still holds",
                            names.join(", ")
                        ),
                    });
                } else {
                    allows.push(AllowEntry {
                        line: cm.line,
                        rules: names,
                        covers: (0, 0),
                        used: false,
                    });
                }
            }
        }
    }

    let module_path = as_path.unwrap_or_else(|| normalize_path(path));
    let toks = strip_test_code(lexed.toks);
    let fns = fn_spans(&toks);
    for a in &mut allows {
        a.covers = resolve_allow_cover(a.line, &toks, &fns);
    }
    let ctx = FileCtx {
        module_path: &module_path,
        toks: &toks,
        fns: &fns,
    };

    for rule in rules::RULES {
        if !rule.applies_to(&module_path) {
            continue;
        }
        let mut raw: Vec<RawDiag> = Vec::new();
        (rule.check)(&ctx, &mut raw);
        for d in raw {
            let allow = allows.iter_mut().find(|a| {
                a.rules.iter().any(|r| r == rule.name)
                    && a.covers.0 <= d.line
                    && d.line <= a.covers.1
            });
            match allow {
                Some(a) => a.used = true,
                None => diags.push(Diagnostic {
                    path: path.to_string(),
                    line: d.line,
                    rule: rule.name,
                    msg: d.msg,
                }),
            }
        }
    }

    for a in &allows {
        if !a.used {
            diags.push(Diagnostic {
                path: path.to_string(),
                line: a.line,
                rule: UNUSED_ALLOW,
                msg: format!(
                    "allow({}) suppressed nothing — remove the stale annotation",
                    a.rules.join(", ")
                ),
            });
        }
    }

    diags.sort_by(|x, y| (x.line, x.rule).cmp(&(y.line, y.rule)));
    diags
}

/// Result of linting a path set.
#[derive(Debug, Default)]
pub struct LintReport {
    /// `.rs` files inspected.
    pub files: usize,
    /// All diagnostics, in (path, line) order.
    pub diags: Vec<Diagnostic>,
}

/// Lint files and directory trees (recursively, `.rs` only). Traversal is
/// sorted so output order — like everything else in this crate — is a pure
/// function of the inputs.
pub fn lint_paths(paths: &[String]) -> crate::Result<LintReport> {
    let mut files: Vec<PathBuf> = Vec::new();
    for p in paths {
        collect_rs(Path::new(p), &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut report = LintReport {
        files: files.len(),
        diags: Vec::new(),
    };
    for f in &files {
        let src = std::fs::read_to_string(f)
            .map_err(|e| anyhow::anyhow!("lint: reading {}: {e}", f.display()))?;
        report.diags.extend(lint_source(&f.to_string_lossy(), &src));
    }
    Ok(report)
}

fn collect_rs(p: &Path, out: &mut Vec<PathBuf>) -> crate::Result<()> {
    let meta = std::fs::metadata(p)
        .map_err(|e| anyhow::anyhow!("lint: no such path {}: {e}", p.display()))?;
    if meta.is_dir() {
        let mut children: Vec<PathBuf> = Vec::new();
        for entry in std::fs::read_dir(p)? {
            children.push(entry?.path());
        }
        children.sort();
        for c in children {
            collect_rs(&c, out)?;
        }
    } else if p.extension().is_some_and(|e| e == "rs") {
        out.push(p.to_path_buf());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_finds_src_component() {
        assert_eq!(normalize_path("rust/src/comm/net.rs"), "src/comm/net.rs");
        assert_eq!(normalize_path("src/lib.rs"), "src/lib.rs");
        assert_eq!(normalize_path("tests/fixture.rs"), "tests/fixture.rs");
    }

    #[test]
    fn fn_spans_and_enclosing() {
        let lexed = lexer::lex("fn outer() {\n    let x = 1;\n}\nfn two(a: [u8; 4]) -> u8 { a[0] }\n");
        let toks = lexed.toks;
        let fns = fn_spans(&toks);
        assert_eq!(fns.len(), 2);
        assert_eq!(fns[0].name, "outer");
        assert_eq!(fns[0].header_line, 1);
        assert_eq!(fns[0].close_line, 3);
        assert_eq!(fns[1].name, "two");
    }

    #[test]
    fn test_code_is_stripped() {
        let src = "fn keep() {}\n#[cfg(test)]\nmod tests {\n    fn gone() {}\n}\n#[test]\nfn also_gone() {}\nfn keep2() {}\n";
        let toks = strip_test_code(lexer::lex(src).toks);
        let names: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert!(names.contains(&"keep"));
        assert!(names.contains(&"keep2"));
        assert!(!names.contains(&"gone"));
        assert!(!names.contains(&"also_gone"));
    }

    #[test]
    fn directive_parsing() {
        assert!(parse_directive("plain comment").is_none());
        match parse_directive("ndq-lint: allow(wall-clock) bench timing only") {
            Some(Ok(Directive::Allow { rules, reason })) => {
                assert_eq!(rules, vec!["wall-clock"]);
                assert_eq!(reason, "bench timing only");
            }
            other => panic!("unexpected: {other:?}"),
        }
        match parse_directive(" ndq-lint: as(src/comm/net.rs)") {
            Some(Ok(Directive::As(p))) => assert_eq!(p, "src/comm/net.rs"),
            other => panic!("unexpected: {other:?}"),
        }
        assert!(matches!(parse_directive("ndq-lint: frobnicate"), Some(Err(_))));
    }
}
