//! The repo-invariant rule set.
//!
//! Every rule here is grounded in a contract an existing test suite or
//! ledger depends on (see README "Static invariants"): determinism of
//! fingerprinted runs, panic-free decoding of hostile wire bytes, and the
//! allocation-free decode hot path. Rules are lexical — they match token
//! shapes, not types — so each one is scoped to the modules where the
//! pattern is load-bearing, and every intentional exception must carry an
//! `ndq-lint: allow(<rule>) <reason>` annotation.

use crate::lint::engine::{FileCtx, RawDiag};

/// Where a rule applies, in normalized `src/…` module-path space.
#[derive(Debug, Clone, Copy)]
pub enum Scope {
    /// Everywhere the linter looks.
    Crate,
    /// Only files whose module path starts with one of these prefixes.
    Modules(&'static [&'static str]),
}

/// One lint rule: a name (the `allow(…)` key), a human summary, a module
/// scope, and a token-level checker.
pub struct Rule {
    pub name: &'static str,
    pub summary: &'static str,
    pub scope: Scope,
    pub check: fn(&FileCtx, &mut Vec<RawDiag>),
}

impl Rule {
    /// Whether this rule runs on a file at `module_path`.
    pub fn applies_to(&self, module_path: &str) -> bool {
        match self.scope {
            Scope::Crate => true,
            Scope::Modules(prefixes) => prefixes.iter().any(|p| module_path.starts_with(p)),
        }
    }

    /// Scope rendered for `ndq lint --rules`.
    pub fn scope_label(&self) -> String {
        match self.scope {
            Scope::Crate => "crate-wide".to_string(),
            Scope::Modules(prefixes) => prefixes.join(", "),
        }
    }
}

/// Modules whose outputs are fingerprinted or ledger-billed: canonical
/// iteration order and total float orderings are load-bearing here.
const DETERMINISM_MODULES: &[&str] = &[
    "src/comm/",
    "src/train/",
    "src/testing/",
    "src/quant/",
    "src/coding/",
    "src/stats/",
    "src/sim/",
];

/// Modules that decode wire/envelope bytes: hostile input must surface
/// typed errors, never panics.
const DECODE_MODULES: &[&str] = &["src/comm/net.rs", "src/quant/", "src/coding/"];

/// A function is "on the decode path" when its name carries one of these
/// markers — the lexical approximation of "reachable from hostile bytes".
/// `fill_` covers the chunked kernel entry points (`fill_symbols`,
/// `fill_pow2`, `fill_const`, …) that decode whole symbol chunks at once.
const DECODE_FN_MARKERS: &[&str] = &[
    "decode", "parse", "unpack", "read", "from_", "next_", "indices", "scales", "fill_",
];

/// Keywords that can precede `[` without forming an index expression.
const NON_INDEX_KEYWORDS: &[&str] = &[
    "as", "async", "await", "box", "break", "const", "continue", "crate", "dyn", "else", "enum",
    "extern", "false", "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod", "move",
    "mut", "pub", "ref", "return", "static", "struct", "super", "trait", "true", "type", "unsafe",
    "use", "where", "while", "yield",
];

/// The rule registry, in the order diagnostics are grouped.
pub const RULES: &[Rule] = &[
    Rule {
        name: "wall-clock",
        summary: "no Instant::now/SystemTime::now — virtual-clock billing and fingerprints \
                  must stay pure; allow only reporting/transport-backpressure timers",
        scope: Scope::Crate,
        check: check_wall_clock,
    },
    Rule {
        name: "unordered-iter",
        summary: "no HashMap/HashSet in fingerprinted or ledger modules — iteration order \
                  must be canonical (BTreeMap or explicit sort)",
        scope: Scope::Modules(DETERMINISM_MODULES),
        check: check_unordered_iter,
    },
    Rule {
        name: "float-cmp",
        summary: "no partial_cmp or float-literal ==/!= in fold/selection paths — use \
                  total_cmp (total order, no NaN panic) or an explicit tolerance",
        scope: Scope::Modules(DETERMINISM_MODULES),
        check: check_float_cmp,
    },
    Rule {
        name: "panic-path",
        summary: "no unwrap/expect/panic!/assert!/indexing inside decode-path functions of \
                  wire modules — hostile bytes must surface typed errors",
        scope: Scope::Modules(DECODE_MODULES),
        check: check_panic_path,
    },
    Rule {
        name: "alloc-in-decode",
        summary: "no Vec::new/vec!/to_vec/collect/with_capacity inside `*_into` decode \
                  functions, `fill_*` chunk kernels or `*_ef` encode lanes — the \
                  buffer-reuse contract runs both hot paths on caller-owned scratch",
        scope: Scope::Modules(&[
            "src/comm/",
            "src/quant/",
            "src/coding/",
            "src/prng/",
            "src/testing/",
        ]),
        check: check_alloc_in_decode,
    },
    Rule {
        name: "naked-cast",
        summary: "no bare `as` narrowing on wire length/count fields in framing code — use \
                  try_into / try_from so hostile lengths fail typed",
        scope: Scope::Modules(&["src/comm/net.rs", "src/quant/mod.rs"]),
        check: check_naked_cast,
    },
    Rule {
        name: "unsafe-code",
        summary: "no `unsafe` anywhere — mirrors #![forbid(unsafe_code)] so fixtures and \
                  tooling can't drift from the crate attribute",
        scope: Scope::Crate,
        check: check_unsafe_code,
    },
];

/// Look up a rule by name (used by `ndq lint --rules` and tests).
pub fn rule(name: &str) -> Option<&'static Rule> {
    RULES.iter().find(|r| r.name == name)
}

fn check_wall_clock(ctx: &FileCtx, out: &mut Vec<RawDiag>) {
    let t = ctx.toks;
    for i in 0..t.len() {
        if (t[i].is_ident("Instant") || t[i].is_ident("SystemTime"))
            && i + 2 < t.len()
            && t[i + 1].is_punct("::")
            && t[i + 2].is_ident("now")
        {
            out.push(RawDiag {
                line: t[i].line,
                msg: format!(
                    "`{}::now` reads the wall clock; billed/fingerprinted paths must use \
                     the virtual clock (sim::LinkModel time)",
                    t[i].text
                ),
            });
        }
    }
}

fn check_unordered_iter(ctx: &FileCtx, out: &mut Vec<RawDiag>) {
    for t in ctx.toks {
        if t.is_ident("HashMap") || t.is_ident("HashSet") {
            out.push(RawDiag {
                line: t.line,
                msg: format!(
                    "`{}` iterates in nondeterministic order; fingerprinted/ledger modules \
                     fold in canonical order — use BTreeMap/BTreeSet or sort explicitly",
                    t.text
                ),
            });
        }
    }
}

/// Float literal heuristic: a decimal point or an explicit f32/f64 suffix
/// (hex literals excluded).
fn is_float_literal(text: &str) -> bool {
    !text.starts_with("0x")
        && (text.contains('.') || text.ends_with("f32") || text.ends_with("f64"))
}

fn check_float_cmp(ctx: &FileCtx, out: &mut Vec<RawDiag>) {
    let t = ctx.toks;
    for i in 0..t.len() {
        if t[i].is_ident("partial_cmp") {
            out.push(RawDiag {
                line: t[i].line,
                msg: "`partial_cmp` panics or misorders on NaN; fold/selection paths must \
                      use `total_cmp`"
                    .to_string(),
            });
        }
        if t[i].is_punct("==") || t[i].is_punct("!=") {
            let prev_float = i > 0
                && t[i - 1].kind == crate::lint::lexer::TokKind::Num
                && is_float_literal(&t[i - 1].text);
            let next_float = i + 1 < t.len()
                && t[i + 1].kind == crate::lint::lexer::TokKind::Num
                && is_float_literal(&t[i + 1].text);
            if prev_float || next_float {
                out.push(RawDiag {
                    line: t[i].line,
                    msg: format!(
                        "floating-point `{}` against a literal; compare with an explicit \
                         tolerance or `total_cmp`",
                        t[i].text
                    ),
                });
            }
        }
    }
}

/// Whether token `idx` sits inside a function whose name marks it as a
/// decode-path function.
fn in_decode_fn(ctx: &FileCtx, idx: usize) -> bool {
    ctx.enclosing_fn(idx)
        .map(|f| DECODE_FN_MARKERS.iter().any(|m| f.name.contains(m)))
        .unwrap_or(false)
}

fn check_panic_path(ctx: &FileCtx, out: &mut Vec<RawDiag>) {
    let t = ctx.toks;
    for i in 0..t.len() {
        // `.unwrap()` / `.expect(…)`
        if i > 0
            && t[i - 1].is_punct(".")
            && (t[i].is_ident("unwrap") || t[i].is_ident("expect"))
            && in_decode_fn(ctx, i)
        {
            out.push(RawDiag {
                line: t[i].line,
                msg: format!(
                    "`.{}` on a decode path can panic on hostile bytes — return a typed \
                     error instead",
                    t[i].text
                ),
            });
            continue;
        }
        // panicking macros
        if i + 1 < t.len()
            && t[i + 1].is_punct("!")
            && ["panic", "unreachable", "todo", "unimplemented", "assert", "assert_eq", "assert_ne"]
                .iter()
                .any(|m| t[i].is_ident(m))
            && in_decode_fn(ctx, i)
        {
            out.push(RawDiag {
                line: t[i].line,
                msg: format!(
                    "`{}!` on a decode path panics on hostile bytes — use anyhow::ensure!/\
                     bail! to surface a typed error",
                    t[i].text
                ),
            });
            continue;
        }
        // index expressions: `expr[…]` where expr ends in an identifier,
        // `)` or `]` (attribute `#[`, `vec![`, array types `&[…]` etc. are
        // preceded by other punctuation and don't match)
        if t[i].is_punct("[") && i > 0 && in_decode_fn(ctx, i) {
            let p = &t[i - 1];
            let indexes = match p.kind {
                crate::lint::lexer::TokKind::Ident => {
                    !NON_INDEX_KEYWORDS.contains(&p.text.as_str())
                }
                crate::lint::lexer::TokKind::Punct => p.text == ")" || p.text == "]",
                _ => false,
            };
            if indexes {
                out.push(RawDiag {
                    line: t[i].line,
                    msg: "slice indexing on a decode path panics out of bounds — use `get` \
                          with a typed error, or allow() stating the bounding invariant"
                        .to_string(),
                });
            }
        }
    }
}

fn check_alloc_in_decode(ctx: &FileCtx, out: &mut Vec<RawDiag>) {
    let t = ctx.toks;
    for f in ctx.fns {
        // `*_into` decoders reuse caller buffers; `fill_*` chunk kernels
        // (symbol unpackers, dither fills) sit inside those hot loops; and
        // `*_ef` encode lanes (per-round error-feedback carries) run every
        // round on every worker, so they share the same contract — pooled
        // scratch may resize/clear/push, but never construct fresh buffers
        if !(f.name.ends_with("_into") || f.name.starts_with("fill_") || f.name.ends_with("_ef"))
        {
            continue;
        }
        for i in f.open_idx..f.end_idx.min(t.len()) {
            // `Vec::new`, `Vec::with_capacity`, `Box::new`, `String::from`…
            let ctor = i + 2 < t.len()
                && ["Vec", "Box", "String", "VecDeque", "BTreeMap", "BTreeSet"]
                    .iter()
                    .any(|c| t[i].is_ident(c))
                && t[i + 1].is_punct("::")
                && ["new", "with_capacity", "from"].iter().any(|m| t[i + 2].is_ident(m));
            // `vec![…]`
            let vec_macro = i + 1 < t.len() && t[i].is_ident("vec") && t[i + 1].is_punct("!");
            // allocating methods
            let method = i > 0
                && t[i - 1].is_punct(".")
                && ["to_vec", "to_owned", "to_string", "collect"]
                    .iter()
                    .any(|m| t[i].is_ident(m));
            if ctor || vec_macro || method {
                out.push(RawDiag {
                    line: t[i].line,
                    msg: format!(
                        "heap allocation in `{}` — `*_into` decoders and `*_ef` encode \
                         lanes run on the allocation-free hot path and must reuse \
                         caller-owned buffers",
                        f.name
                    ),
                });
            }
        }
    }
}

/// Integer types a bare `as` cast can silently truncate or re-sign into.
const NARROWING_TARGETS: &[&str] = &["u8", "u16", "u32", "i8", "i16", "i32", "usize"];

fn check_naked_cast(ctx: &FileCtx, out: &mut Vec<RawDiag>) {
    let t = ctx.toks;
    for i in 0..t.len() {
        if t[i].is_ident("as")
            && i + 1 < t.len()
            && NARROWING_TARGETS.iter().any(|ty| t[i + 1].is_ident(ty))
        {
            out.push(RawDiag {
                line: t[i].line,
                msg: format!(
                    "bare `as {}` can silently truncate a wire length/count — use \
                     `try_from`/`try_into` or an annotated checked helper",
                    t[i + 1].text
                ),
            });
        }
    }
}

fn check_unsafe_code(ctx: &FileCtx, out: &mut Vec<RawDiag>) {
    for t in ctx.toks {
        if t.is_ident("unsafe") {
            out.push(RawDiag {
                line: t.line,
                msg: "`unsafe` is forbidden in this crate (#![forbid(unsafe_code)]); no \
                      module needs it"
                    .to_string(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_kebab_case() {
        let mut names: Vec<&str> = RULES.iter().map(|r| r.name).collect();
        names.sort();
        let before = names.len();
        names.dedup();
        assert_eq!(names.len(), before);
        for n in names {
            assert!(
                n.chars().all(|c| c.is_ascii_lowercase() || c == '-'),
                "rule name {n} not kebab-case"
            );
        }
    }

    #[test]
    fn scopes_resolve() {
        let wall = rule("wall-clock").unwrap();
        assert!(wall.applies_to("src/anything.rs"));
        let panic = rule("panic-path").unwrap();
        assert!(panic.applies_to("src/comm/net.rs"));
        assert!(panic.applies_to("src/quant/dithered.rs"));
        assert!(!panic.applies_to("src/train/trainer.rs"));
        let cast = rule("naked-cast").unwrap();
        assert!(cast.applies_to("src/quant/mod.rs"));
        assert!(!cast.applies_to("src/quant/dithered.rs"));
        // the chunked-kernel extension: alloc checks cover the dither fill
        // in prng, but prng stays outside the panic-path (hostile-bytes)
        // scope — its inputs are locally generated blocks, not wire bytes
        let alloc = rule("alloc-in-decode").unwrap();
        assert!(alloc.applies_to("src/prng/mod.rs"));
        assert!(alloc.applies_to("src/coding/pack.rs"));
        // the event-loop extension: the leader hot loop in src/testing/
        // carries the same buffer-reuse contract as the codec kernels
        assert!(alloc.applies_to("src/testing/cluster.rs"));
        assert!(!panic.applies_to("src/testing/cluster.rs"));
        assert!(!panic.applies_to("src/prng/mod.rs"));
    }

    #[test]
    fn decode_markers_cover_fill_kernels() {
        for name in ["fill_symbols", "fill_pow2", "fill_const", "fill_dither"] {
            assert!(
                DECODE_FN_MARKERS.iter().any(|m| name.contains(m)),
                "{name} should be decode-marked"
            );
        }
        // the enum-dispatch wrapper `fill` is not itself a kernel body
        assert!(!DECODE_FN_MARKERS.iter().any(|m| "fill".contains(m)));
    }

    #[test]
    fn ef_encode_lanes_are_alloc_checked() {
        // the EF extension: `*_ef` functions share the `*_into` buffer-reuse
        // contract, while same-shaped functions without the suffix do not
        let src = "// ndq-lint: as(src/quant/x.rs)\n\
                   fn carry_ef(out: &mut [f32]) {\n\
                       let t: Vec<f32> = out.iter().copied().collect();\n\
                       out.copy_from_slice(&t);\n\
                   }\n\
                   fn carry(out: &[f32]) -> Vec<f32> {\n\
                       out.to_vec()\n\
                   }\n";
        let d = crate::lint::lint_source("tests/x.rs", src);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!((d[0].rule, d[0].line), ("alloc-in-decode", 3));
    }

    #[test]
    fn float_literal_heuristic() {
        assert!(is_float_literal("1.0"));
        assert!(is_float_literal("1.0e-3"));
        assert!(is_float_literal("2f64"));
        assert!(!is_float_literal("42"));
        assert!(!is_float_literal("0xff"));
    }
}
