//! Flat f32 tensor math used by the L3 hot path (no BLAS dependency).
//!
//! The coordinator mostly works on *flat parameter/gradient vectors* (the
//! ABI shared with the AOT artifacts), so this module is vector math plus a
//! few norm/statistics helpers shared by the quantizers and optimizers.

/// max_i |x_i| — the paper's scale factor kappa (guarded against all-zero).
#[inline]
pub fn linf_norm(x: &[f32]) -> f32 {
    let mut m = 0f32;
    for &v in x {
        let a = v.abs();
        if a > m {
            m = a;
        }
    }
    if m > 0.0 {
        m
    } else {
        1.0
    }
}

/// ||x||_2
pub fn l2_norm(x: &[f32]) -> f32 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt() as f32
}

/// ||a - b||_2^2 (f64 accumulation)
pub fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum()
}

/// y += alpha * x
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// y = x (copy)
pub fn copy(x: &[f32], y: &mut [f32]) {
    y.copy_from_slice(x);
}

/// x *= alpha
pub fn scale(alpha: f32, x: &mut [f32]) {
    for v in x {
        *v *= alpha;
    }
}

/// out = mean of rows
pub fn mean_rows(rows: &[&[f32]], out: &mut [f32]) {
    assert!(!rows.is_empty());
    let inv = 1.0 / rows.len() as f32;
    out.fill(0.0);
    for row in rows {
        assert_eq!(row.len(), out.len());
        for (o, &v) in out.iter_mut().zip(*row) {
            *o += v;
        }
    }
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// Mean and (population) variance with f64 accumulation.
pub fn mean_var(x: &[f32]) -> (f64, f64) {
    let n = x.len().max(1) as f64;
    let mean = x.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = x.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    (mean, var)
}

/// Argmax index (first max wins).
pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in x.iter().enumerate() {
        if v > x[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms() {
        assert_eq!(linf_norm(&[0.5, -2.0, 1.0]), 2.0);
        assert_eq!(linf_norm(&[0.0, 0.0]), 1.0); // guard
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn axpy_and_mean_rows() {
        let mut y = vec![1.0f32, 2.0];
        axpy(2.0, &[10.0, 20.0], &mut y);
        assert_eq!(y, vec![21.0, 42.0]);

        let a = [1.0f32, 2.0];
        let b = [3.0f32, 6.0];
        let mut out = vec![0f32; 2];
        mean_rows(&[&a, &b], &mut out);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    fn stats() {
        let (m, v) = mean_var(&[1.0, 2.0, 3.0, 4.0]);
        assert!((m - 2.5).abs() < 1e-12);
        assert!((v - 1.25).abs() < 1e-12);
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
    }

    #[test]
    fn sq_dist_f64_accumulation() {
        let a = vec![1e-4f32; 10_000];
        let b = vec![0f32; 10_000];
        let d = sq_dist(&a, &b);
        assert!((d - 10_000.0 * 1e-8).abs() < 1e-9);
    }
}
