//! Cross-codec equivalence + ledger-truth acceptance suite for wire v3.
//!
//! Pins the three contract points of shipping entropy-coded payloads:
//!
//! 1. **Fold invariance** — a session decodes raw, huffman and aac
//!    messages over the same (gradient, dither) to bit-identical
//!    aggregates, including rounds that *mix* codecs across workers and
//!    NDQSG (Alg. 2) scheme mixes.
//! 2. **Ledger = wire truth** — with `codec = aac`, the session's
//!    `total_aac_bits` equals the transmitted payload bits exactly, sits
//!    within 2% of the entropy limit on gradient-like streams, and the
//!    `transmitted` lane shows the real on-wire saving against base-k.
//! 3. **Encode-time metrics** — the ledger the session accumulates from
//!    carried [`ndq::quant::BitMetrics`] equals what the old re-decode
//!    path (now `WireMsg::derive_metrics`) reconstructs from payload
//!    bytes, with zero fallbacks — the regression pin that let
//!    `CommStats` stop re-decoding every message of every round.

use ndq::comm::{Session, WorkerMsg};
use ndq::prng::DitherStream;
use ndq::quant::{GradQuantizer, PayloadCodec, Scheme};
use ndq::testing::cluster::{run_scenario, ClusterScenario};

fn correlated(n: usize, workers: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = ndq::prng::Xoshiro256::new(seed);
    let base: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.2).collect();
    (0..workers)
        .map(|_| base.iter().map(|&b| b + rng.next_normal() * 0.01).collect())
        .collect()
}

fn encode_round(
    schemes: &[Scheme],
    gs: &[Vec<f32>],
    run_seed: u64,
    round: u64,
    codecs: &[PayloadCodec],
) -> Vec<WorkerMsg> {
    gs.iter()
        .enumerate()
        .map(|(p, g)| {
            let mut q = schemes[p].build();
            let stream = DitherStream::new(run_seed, p as u32);
            let wire = q.encode_coded(g, &mut stream.round(round), codecs[p % codecs.len()]);
            WorkerMsg::new(p, round, 0.0, wire)
        })
        .collect()
}

#[test]
fn aac_run_ledger_is_wire_truth_and_folds_match_raw() {
    let n = 20_000;
    let workers = 4;
    let rounds = 3u64;
    let schemes = vec![Scheme::Dithered { delta: 1.0 / 3.0 }; workers];

    let mut s_raw = Session::new(&schemes, 11, n).unwrap();
    let mut s_aac = Session::new(&schemes, 11, n).unwrap();
    let mut wire_payload_bits = 0u64;
    for round in 0..rounds {
        let gs = correlated(n, workers, 100 + round);
        let raw_msgs = encode_round(&schemes, &gs, 11, round, &[PayloadCodec::Raw]);
        let aac_msgs = encode_round(&schemes, &gs, 11, round, &[PayloadCodec::Aac]);
        // the transmitted ledger must equal what the frame headers say
        // actually crossed the wire
        for m in &aac_msgs {
            wire_payload_bits += m.wire.transmitted_bits() as u64;
        }
        let a_raw = s_raw.decode_round(&raw_msgs).unwrap();
        let a_aac = s_aac.decode_round(&aac_msgs).unwrap();
        assert_eq!(a_raw, a_aac, "round {round}: aac fold diverged from raw");
    }

    let st = s_aac.stats();
    assert_eq!(st.metric_fallback_frames, 0);
    // ledger = wire truth, to the bit
    assert_eq!(st.total_transmitted_bits, wire_payload_bits as f64);
    assert_eq!(st.total_aac_bits, st.total_transmitted_bits);
    // within 2% of the entropy limit on these gradient streams
    let ratio = st.total_aac_bits / st.total_entropy_bits;
    assert!((0.98..1.02).contains(&ratio), "aac/entropy = {ratio}");
    // and the win against fixed-rate base-k is real and recorded
    assert!(
        st.total_transmitted_bits < st.total_raw_bits,
        "coded wire must ship fewer bits than the base-k equivalent"
    );
    // the raw-codec session bills transmitted == raw (same indices)
    let rt = s_raw.stats();
    assert_eq!(rt.total_transmitted_bits, rt.total_raw_bits);
    assert_eq!(rt.total_raw_bits, st.total_raw_bits, "Table-1 metric is codec-free");
    assert_eq!(rt.total_entropy_bits, st.total_entropy_bits);
}

#[test]
fn mixed_codec_rounds_fold_identically_including_ndqsg() {
    let n = 3000;
    let mixes: Vec<Vec<Scheme>> = vec![
        vec![Scheme::Dithered { delta: 1.0 / 3.0 }; 3],
        vec![
            Scheme::Dithered { delta: 1.0 / 3.0 },
            Scheme::Dithered { delta: 1.0 / 3.0 },
            Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
            Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
        ],
    ];
    for schemes in mixes {
        let gs = correlated(n, schemes.len(), 7);
        let mut uniform = Session::new(&schemes, 3, n).unwrap();
        let want = uniform
            .decode_round(&encode_round(&schemes, &gs, 3, 0, &[PayloadCodec::Raw]))
            .unwrap();
        // one codec per worker, round-robin: raw, huffman, aac, raw, ...
        let mixed_msgs = encode_round(
            &schemes,
            &gs,
            3,
            0,
            &[PayloadCodec::Raw, PayloadCodec::Huffman, PayloadCodec::Aac],
        );
        let mut mixed = Session::new(&schemes, 3, n).unwrap();
        let got = mixed.decode_round(&mixed_msgs).unwrap();
        assert_eq!(want, got, "{}-worker mixed-codec round diverged", schemes.len());
        // arrival order still immaterial with mixed codecs
        let mut agg_session = Session::new(&schemes, 3, n).unwrap();
        let mut agg = agg_session.begin_round();
        for m in mixed_msgs.iter().rev() {
            agg.push(m.clone()).unwrap();
        }
        assert_eq!(agg.finish().unwrap(), want);
    }
}

#[test]
fn session_ledger_equals_rederived_payload_metrics() {
    // encode-time accounting (what the session records) == the old
    // decode-the-payload accounting, message for message
    let n = 5000;
    let schemes = vec![
        Scheme::Dithered { delta: 0.5 },
        Scheme::Qsgd { m: 2 },
        Scheme::Terngrad,
        Scheme::OneBit,
    ];
    for codec in [PayloadCodec::Raw, PayloadCodec::Huffman, PayloadCodec::Aac] {
        let gs = correlated(n, schemes.len(), 21);
        let msgs = encode_round(&schemes, &gs, 5, 0, &[codec]);
        let mut session = Session::new(&schemes, 5, n).unwrap();
        session.decode_round(&msgs).unwrap();
        let st = session.stats();

        let mut raw = 0u64;
        let mut transmitted = 0u64;
        let mut entropy = 0f64;
        let mut aac = 0f64;
        for m in &msgs {
            // re-derive from the parsed wire bytes alone — the path the
            // ledger no longer runs per round
            let reparsed = ndq::quant::WireMsg::parse(m.wire.bytes().to_vec()).unwrap();
            let d = reparsed.derive_metrics(codec == PayloadCodec::Aac);
            assert_eq!(d.fallback_frames, 0);
            raw += d.raw_bits;
            transmitted += d.transmitted_bits;
            entropy += d.entropy_bits;
            if let Some(a) = d.aac_bits {
                aac += a as f64;
            }
        }
        assert_eq!(st.total_raw_bits, raw as f64, "{codec:?}: raw ledger");
        assert_eq!(
            st.total_transmitted_bits, transmitted as f64,
            "{codec:?}: transmitted ledger"
        );
        assert_eq!(st.total_entropy_bits, entropy, "{codec:?}: entropy ledger");
        if codec == PayloadCodec::Aac {
            assert_eq!(st.total_aac_bits, aac, "aac ledger");
        }
        assert_eq!(st.metric_fallback_frames, 0);
    }
}

#[test]
fn cluster_training_is_codec_invariant_but_cheaper_on_the_wire() {
    // end to end through the scenario engine: same seed, raw vs aac —
    // identical training trajectory, smaller transmitted ledger
    let base = ClusterScenario {
        workers: 4,
        n_params: 1500,
        rounds: 12,
        eval_every: 4,
        ..ClusterScenario::default()
    };
    let raw = run_scenario(ClusterScenario { codec: PayloadCodec::Raw, ..base.clone() }).unwrap();
    let aac = run_scenario(ClusterScenario { codec: PayloadCodec::Aac, ..base.clone() }).unwrap();

    assert_eq!(raw.history.len(), aac.history.len());
    for (a, b) in raw.history.iter().zip(&aac.history) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "round {}", a.round);
        assert_eq!(a.eval_loss.to_bits(), b.eval_loss.to_bits(), "round {}", a.round);
    }
    assert_eq!(raw.final_eval_loss.to_bits(), aac.final_eval_loss.to_bits());
    assert_eq!(raw.delivery, aac.delivery);
    // identical Table-1/entropy ledgers, strictly cheaper wire
    assert_eq!(raw.comm.total_raw_bits, aac.comm.total_raw_bits);
    assert_eq!(raw.comm.total_entropy_bits, aac.comm.total_entropy_bits);
    assert!(aac.comm.total_transmitted_bits < raw.comm.total_transmitted_bits);
    assert_eq!(aac.comm.total_aac_bits, aac.comm.total_transmitted_bits);
    assert_eq!(aac.comm.metric_fallback_frames, 0);
}
