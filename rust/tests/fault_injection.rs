//! Fault-injected exchange suite: determinism, no-fault policy
//! equivalence, per-fault ledger attribution, and NDQSG degraded-round
//! semantics — the acceptance criteria of the fault-channel layer, run
//! entirely on the artifact-free scenario engine and raw sessions.

use ndq::comm::{ExchangeError, FaultChannel, FaultPlan, RoundPolicy, Session, WorkerMsg};
use ndq::prng::{DitherStream, Xoshiro256};
use ndq::quant::{GradQuantizer, Scheme};
use ndq::sim::LinkModel;
use ndq::testing::cluster::{run_scenario, ClusterScenario};
use ndq::testing::{gens, prop_check};

fn correlated(n: usize, p: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Xoshiro256::new(seed);
    let base: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.2).collect();
    (0..p)
        .map(|_| base.iter().map(|&b| b + rng.next_normal() * 0.01).collect())
        .collect()
}

fn make_msgs(schemes: &[Scheme], gs: &[Vec<f32>], run_seed: u64, round: u64) -> Vec<WorkerMsg> {
    gs.iter()
        .enumerate()
        .map(|(p, g)| {
            let mut q = schemes[p].build();
            let stream = DitherStream::new(run_seed, p as u32);
            WorkerMsg::new(p, round, 0.25, q.encode(g, &mut stream.round(round)))
        })
        .collect()
}

// ---- acceptance: determinism ------------------------------------------------

#[test]
fn same_seed_same_plan_bit_identical_report() {
    let scenario = || ClusterScenario {
        workers: 6,
        rounds: 25,
        seed: 99,
        scheme: Scheme::Dithered { delta: 1.0 / 3.0 },
        scheme_p2: Some(Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 }),
        plan: FaultPlan::new()
            .drop_prob(0.15)
            .corrupt_prob(0.05)
            .straggle(2, 50.0)
            .delay_at(1, 3, 2)
            .duplicate_at(0, 4)
            .disconnect_at(5, 15),
        policy: RoundPolicy::Quorum(3),
        ..ClusterScenario::default()
    };
    let a = run_scenario(scenario()).unwrap();
    let b = run_scenario(scenario()).unwrap();
    assert_eq!(
        a.fingerprint(),
        b.fingerprint(),
        "same seed + same plan must give a bit-identical TrainReport"
    );
    // spot-check the underlying fields too, not just the digest
    assert_eq!(a.delivery, b.delivery);
    assert_eq!(a.rounds_failed, b.rounds_failed);
    assert_eq!(a.final_eval_loss.to_bits(), b.final_eval_loss.to_bits());
    assert_eq!(a.comm.dropped_bits, b.comm.dropped_bits);
    assert_eq!(a.comm.total_raw_bits.to_bits(), b.comm.total_raw_bits.to_bits());
    // and the faults actually fired
    assert!(a.comm.dropped_msgs > 0, "plan injected no drops?");
    assert_eq!(a.comm.disconnects, 1);

    // a different seed changes the fault schedule and the trajectory
    let mut other = scenario();
    other.seed = 100;
    let c = run_scenario(other).unwrap();
    assert_ne!(a.fingerprint(), c.fingerprint());
}

#[test]
fn report_json_is_parseable_despite_non_finite_fields() {
    // Every synthetic-task report carries NaN accuracy (and a degraded run
    // can add NaN train losses); `Json::Num` used to print those as
    // literal `NaN` — not a JSON token — corrupting `--report` files and
    // the `--bench-append` trajectory. They must serialize as `null` and
    // round-trip through the parser.
    let report = run_scenario(ClusterScenario {
        workers: 4,
        rounds: 8,
        // a plan aggressive enough to fail rounds -> NaN train losses
        plan: FaultPlan::new().drop_prob(0.9),
        policy: RoundPolicy::Quorum(4),
        ..ClusterScenario::default()
    })
    .unwrap();
    assert!(
        !report.final_accuracy.is_finite() || report.rounds_failed > 0,
        "scenario no longer produces any non-finite field; pick a harsher one"
    );
    let text = report.to_json().to_string();
    assert!(!text.contains("NaN") && !text.contains("inf"), "{text}");
    ndq::util::json::Json::parse(&text).expect("report JSON must re-parse");
}

// ---- acceptance: no-fault equivalence ---------------------------------------

#[test]
fn prop_policies_equal_waitall_on_clean_link() {
    // Quorum(P) and Deadline(inf) with an empty fault plan must produce
    // bit-identical aggregates to WaitAll — over scheme mixes including
    // NDQSG, and under reversed arrival order.
    prop_check(
        "no-fault-policy-equivalence",
        12,
        gens::pair(gens::f32_vec(900), gens::seed()),
        |(base, seed)| {
            let n = base.len().max(8);
            let mixes: Vec<Vec<Scheme>> = vec![
                vec![Scheme::Dithered { delta: 0.5 }; 4],
                vec![
                    Scheme::Dithered { delta: 1.0 / 3.0 },
                    Scheme::Dithered { delta: 1.0 / 3.0 },
                    Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
                    Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
                ],
                vec![
                    Scheme::Qsgd { m: 2 },
                    Scheme::Terngrad,
                    Scheme::Dithered { delta: 0.5 },
                    Scheme::Nested { d1: 0.25, ratio: 3, alpha: 1.0 },
                ],
            ];
            for schemes in mixes {
                let gs = correlated(n, schemes.len(), *seed);
                let msgs = make_msgs(&schemes, &gs, *seed, 1);
                let mut reference = Session::new(&schemes, *seed, n)
                    .map_err(|e| e.to_string())?;
                let want = reference.decode_round(&msgs).map_err(|e| e.to_string())?;

                let p = schemes.len();
                for policy in [
                    RoundPolicy::WaitAll,
                    RoundPolicy::Quorum(p),
                    RoundPolicy::Deadline(f64::INFINITY),
                ] {
                    for reverse in [false, true] {
                        let mut session = Session::new(&schemes, *seed, n)
                            .map_err(|e| e.to_string())?;
                        let mut channel = FaultChannel::new(
                            FaultPlan::default(),
                            *seed,
                            p,
                            LinkModel::gigabit(),
                        );
                        let mut events = Vec::new();
                        for m in msgs.iter().cloned() {
                            events.extend(channel.feed(m));
                        }
                        if reverse {
                            events.reverse();
                        }
                        let mut ex = session.begin_exchange(1, policy);
                        for ev in events {
                            ex.offer(ev);
                        }
                        if !ex.is_complete() {
                            return Err(format!("{policy:?}: round did not complete"));
                        }
                        let out = ex.finish().map_err(|e| e.to_string())?;
                        if out.average != want {
                            return Err(format!(
                                "{policy:?} (reverse={reverse}) diverged from WaitAll"
                            ));
                        }
                        if out.received != p || out.expected != p {
                            return Err(format!("{policy:?}: delivery {:?}", (out.received, out.expected)));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

// ---- scenario: uniform drop under quorum ------------------------------------

#[test]
fn uniform_drop_quorum_degrades_gracefully() {
    let report = run_scenario(ClusterScenario {
        workers: 8,
        rounds: 40,
        plan: FaultPlan::new().drop_prob(0.10),
        policy: RoundPolicy::Quorum(5),
        ..ClusterScenario::default()
    })
    .unwrap();
    let received: u64 = report.delivery.iter().map(|d| d.received as u64).sum();
    let expected: u64 = report.delivery.iter().map(|d| d.expected as u64).sum();
    assert!(report.comm.dropped_msgs > 0, "10% drop fired nothing in 320 messages");
    assert!(received < expected);
    assert_eq!(
        received + report.comm.dropped_msgs + report.comm.late_msgs,
        expected,
        "every expected message must be attributed: folded, dropped, or late"
    );
    // the fold scales by 1/|received|, so training still converges
    assert_eq!(report.rounds_failed, 0);
    assert!(report.final_eval_loss < 0.02, "{}", report.final_eval_loss);
    // dropped bits were attributed
    assert!(report.comm.dropped_bits > 0);
}

// ---- scenario: delay = dropped-now, late-later ------------------------------

#[test]
fn delayed_message_is_stale_on_release() {
    let report = run_scenario(ClusterScenario {
        rounds: 6,
        plan: FaultPlan::new().delay_at(1, 0, 2),
        ..ClusterScenario::default()
    })
    .unwrap();
    // round 0: worker 1's message is withheld (tombstone = dropped)
    assert_eq!(report.delivery[0], ndq::train::RoundDelivery { received: 3, expected: 4 });
    // round 2: the stale round-0 message arrives and is rejected as late
    assert_eq!(report.comm.dropped_msgs, 1);
    assert_eq!(report.comm.late_msgs, 1);
    assert!(report.comm.late_bits > 0);
    // every other round is full
    for (r, d) in report.delivery.iter().enumerate() {
        if r != 0 {
            assert_eq!((d.received, d.expected), (4, 4), "round {r}");
        }
    }
    assert_eq!(report.rounds_failed, 0);
}

// ---- scenario: duplicates never poison the fold -----------------------------

#[test]
fn duplicate_counted_once_in_fold() {
    let n = 500;
    let schemes = vec![Scheme::Dithered { delta: 0.5 }; 3];
    let gs = correlated(n, 3, 7);
    let msgs = make_msgs(&schemes, &gs, 7, 0);

    let mut clean = Session::new(&schemes, 7, n).unwrap();
    let want = clean.decode_round(&msgs).unwrap();

    let mut session = Session::new(&schemes, 7, n).unwrap();
    let mut channel = FaultChannel::new(
        FaultPlan::new().duplicate_at(1, 0),
        7,
        3,
        LinkModel::gigabit(),
    );
    let mut ex = session.begin_exchange(0, RoundPolicy::WaitAll);
    let mut total_events = 0;
    for m in msgs {
        for ev in channel.feed(m) {
            total_events += 1;
            ex.offer(ev);
        }
    }
    assert_eq!(total_events, 4, "duplicate fault must emit two copies");
    let out = ex.finish().unwrap();
    assert_eq!(out.average, want, "duplicate changed the aggregate");
    assert_eq!(out.received, 3);
    assert_eq!(session.stats().duplicate_msgs, 1);
    assert!(session.stats().duplicate_bits > 0);
    assert_eq!(session.stats().messages, 3, "ledger counts each worker once");
}

// ---- scenario: disconnect shrinks later rounds ------------------------------

#[test]
fn disconnect_shrinks_expected_from_next_round() {
    let report = run_scenario(ClusterScenario {
        rounds: 6,
        plan: FaultPlan::new().disconnect_at(3, 2),
        ..ClusterScenario::default()
    })
    .unwrap();
    let de: Vec<(u32, u32)> = report.delivery.iter().map(|d| (d.received, d.expected)).collect();
    // rounds 0-1 full; round 2 sees the tombstone (expected still counts the
    // worker at round start); rounds 3+ exclude it entirely
    assert_eq!(de[0], (4, 4));
    assert_eq!(de[1], (4, 4));
    assert_eq!(de[2], (3, 4));
    for (r, &d) in de.iter().enumerate().skip(3) {
        assert_eq!(d, (3, 3), "round {r}");
    }
    assert_eq!(report.comm.disconnects, 1);
    assert_eq!(report.rounds_failed, 0);
    assert!(report.final_eval_loss < 0.02);
}

// ---- NDQSG: bootstrap-missing is typed, never mis-decoded -------------------

#[test]
fn ndqsg_bootstrap_missing_is_typed_error() {
    let n = 400;
    let schemes = vec![
        Scheme::Dithered { delta: 1.0 / 3.0 },
        Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
        Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
    ];
    let gs = correlated(n, 3, 11);
    let msgs = make_msgs(&schemes, &gs, 11, 0);

    let mut session = Session::new(&schemes, 11, n).unwrap();
    // the lone P1 worker's message is dropped on the link
    let mut channel = FaultChannel::new(
        FaultPlan::new().drop_at(0, 0),
        11,
        3,
        LinkModel::gigabit(),
    );
    let mut ex = session.begin_exchange(0, RoundPolicy::Quorum(2));
    for m in msgs {
        for ev in channel.feed(m) {
            ex.offer(ev);
        }
    }
    assert!(ex.is_complete(), "quorum of 2 valid P2 messages was reached");
    let err = ex.finish().unwrap_err();
    match err {
        ExchangeError::NdqsgBootstrapMissing { round, queued_p2 } => {
            assert_eq!(round, 0);
            assert_eq!(queued_p2, 2);
        }
        other => panic!("wanted NdqsgBootstrapMissing, got {other:?}"),
    }
    // the queued-then-failed P2 bits are attributed as rejected
    assert_eq!(session.stats().rejected_msgs, 2);
    assert_eq!(session.stats().dropped_msgs, 1);
    // the session recovers: the next round with full delivery succeeds
    // (WaitAll here — under Quorum(2) the third arrival would count late)
    let gs2 = correlated(n, 3, 12);
    let msgs2 = make_msgs(&schemes, &gs2, 11, 1);
    let mut channel2 = FaultChannel::new(FaultPlan::default(), 11, 3, LinkModel::gigabit());
    let mut ex = session.begin_exchange(1, RoundPolicy::WaitAll);
    for m in msgs2 {
        for ev in channel2.feed(m) {
            ex.offer(ev);
        }
    }
    let out = ex.finish().unwrap();
    assert_eq!(out.received, 3);
}

#[test]
fn ndqsg_bootstrap_failure_survivable_in_harness() {
    // the scenario engine records the failed round and keeps training
    let report = run_scenario(ClusterScenario {
        workers: 3, // worker 0 is the only P1 under the half-split rule
        rounds: 10,
        scheme_p2: Some(Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 }),
        plan: FaultPlan::new().drop_at(0, 4),
        policy: RoundPolicy::Quorum(2),
        ..ClusterScenario::default()
    })
    .unwrap();
    assert_eq!(report.rounds_failed, 1);
    assert_eq!(report.delivery[4].received, 0);
    assert_eq!(report.delivery[4].expected, 3);
    assert!(report.final_eval_loss < 0.05, "{}", report.final_eval_loss);
}

// ---- deadline + straggler interplay -----------------------------------------

#[test]
fn deadline_infinity_never_rejects_and_tight_deadline_does() {
    let mk = |deadline: f64| {
        run_scenario(ClusterScenario {
            rounds: 8,
            plan: FaultPlan::new().straggle(1, 1_000_000.0),
            policy: RoundPolicy::Deadline(deadline),
            ..ClusterScenario::default()
        })
        .unwrap()
    };
    let inf = mk(f64::INFINITY);
    assert_eq!(inf.comm.late_msgs, 0);
    assert!(inf.delivery.iter().all(|d| d.received == 4));

    let tight = mk(0.05);
    assert_eq!(tight.comm.late_msgs, 8, "straggler late every round");
    assert!(tight.delivery.iter().all(|d| d.received == 3 && d.expected == 4));
}

// ---- fault decisions vs. worker identity ------------------------------------

#[test]
fn scripted_fault_hits_exactly_its_target() {
    // one corrupt byte for worker 2 at round 3 only: the ledger shows one
    // CRC rejection and every other (worker, round) folds
    let report = run_scenario(ClusterScenario {
        rounds: 6,
        plan: FaultPlan::new().corrupt_at(2, 3),
        ..ClusterScenario::default()
    })
    .unwrap();
    assert_eq!(report.comm.rejected_msgs, 1);
    assert!(report.comm.rejected_bits > 0);
    assert_eq!(report.delivery[3], ndq::train::RoundDelivery { received: 3, expected: 4 });
    let folded: u64 = report.delivery.iter().map(|d| d.received as u64).sum();
    assert_eq!(folded, 6 * 4 - 1);
}
