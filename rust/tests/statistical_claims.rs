//! The paper's statistical claims as executable assertions.
//!
//! What separates DQSG/NDQSG from QSGD-style quantizers (Thm. 1, Lemma 3,
//! Thms. 5-6) is the *shape* of the reconstruction error: subtractive
//! dithering makes `(g~ - g)/kappa` exactly uniform on [-Δ/2, Δ/2],
//! independent of the gradient — so quantized training behaves like plain
//! SG plus bounded iid noise. This suite measures those properties on the
//! real encode → wire bytes → decode path:
//!
//! 1. Kolmogorov–Smirnov: the normalized error's empirical CDF matches the
//!    uniform CDF at n ≥ 10^5 samples (α = 0.01 band).
//! 2. Input-independence: the error is uncorrelated with the gradient, and
//!    its variance does not depend on |g| — while QSGD's demonstrably does
//!    (the contrast that motivates dithering).
//! 3. Variance bound: per-element error variance ≤ Δ²/12 (1 + tol).
//! 4. NDQSG ≤ DQSG: same error variance at the same fine step while the
//!    `CommStats` ledger bills strictly fewer payload bits per round
//!    (Thms. 5-6 / Fig. 6).
//!
//! Sample sizes: the default ("quick", what `scripts/tier1.sh` runs) uses
//! 120k samples per scheme; `NDQ_STAT_MODE=full` raises that to 1M for
//! local deep runs. Everything is seeded — the verdicts are deterministic.

use ndq::comm::{Session, WorkerMsg};
use ndq::prng::{DitherStream, Xoshiro256};
use ndq::quant::{GradQuantizer, Scheme};
use ndq::testing::{ks_statistic_uniform, pearson};

/// Per-scheme sample budget: quick (tier-1) vs full (local deep runs).
fn sample_budget() -> usize {
    match std::env::var("NDQ_STAT_MODE").as_deref() {
        Ok("full") => 1_000_000,
        _ => 120_000,
    }
}

const CHUNK: usize = 20_000;

/// The normalized step Δ of a dithered scheme (the uniform error support
/// is [-Δ/2, Δ/2]).
fn delta_of(scheme: &Scheme) -> f32 {
    match scheme {
        Scheme::Dithered { delta } => *delta,
        Scheme::DitheredPartitioned { delta, .. } => *delta,
        Scheme::Nested { d1, .. } => *d1,
        _ => panic!("not a dithered scheme"),
    }
}

/// Per-coordinate kappa for one message: single-scale schemes broadcast
/// scales[0]; partitioned DQSG maps each coordinate to its partition's
/// scale (K near-equal chunks, first n%K one longer — the codec's layout).
fn per_coord_kappa(scheme: &Scheme, scales: &[f32], n: usize) -> Vec<f32> {
    match scheme {
        Scheme::DitheredPartitioned { k, .. } => {
            let k = (*k).min(n.max(1));
            assert_eq!(scales.len(), k);
            let base = n / k;
            let rem = n % k;
            let mut out = Vec::with_capacity(n);
            for (i, &s) in scales.iter().enumerate() {
                let len = base + usize::from(i < rem);
                out.extend(std::iter::repeat(s).take(len));
            }
            out
        }
        _ => {
            assert_eq!(scales.len(), 1);
            vec![scales[0]; n]
        }
    }
}

/// Collect (gradient, normalized error) pairs for `scheme` over enough
/// encode/decode round trips to reach the sample budget. NDQSG decodes
/// against side information y = g + z with |z| safely inside the coarse
/// bin (Thm. 6's exact-decoding regime — the operating point of Alg. 2).
fn error_samples(scheme: Scheme, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let budget = sample_budget();
    let mut gs = Vec::with_capacity(budget);
    let mut errs = Vec::with_capacity(budget);
    let mut rng = Xoshiro256::new(seed);
    let mut q = scheme.build();
    let stream = DitherStream::new(seed ^ 0xD17, 0);
    let mut round = 0u64;
    while gs.len() < budget {
        let g: Vec<f32> = (0..CHUNK).map(|_| rng.next_normal() * 0.25).collect();
        let msg = q.encode(&g, &mut stream.round(round));
        let side_owner;
        let side = if q.needs_side_info() {
            let Scheme::Nested { d1, ratio, alpha } = scheme else { unreachable!() };
            let kappa = g.iter().fold(0f32, |m, &x| m.max(x.abs()));
            let zmax = 0.4 * (d1 * ratio as f32 - d1) / (2.0 * alpha) * kappa;
            side_owner = g
                .iter()
                .map(|&x| x + (rng.next_f32() * 2.0 - 1.0) * zmax)
                .collect::<Vec<f32>>();
            Some(&side_owner[..])
        } else {
            None
        };
        let recon = q.decode(&msg, &mut stream.round(round), side).unwrap();
        let kappas = per_coord_kappa(&scheme, &msg.scales().unwrap(), g.len());
        for ((&gi, &ri), &ki) in g.iter().zip(&recon).zip(&kappas) {
            gs.push(gi as f64);
            errs.push((ri - gi) as f64 / ki as f64);
        }
        round += 1;
    }
    gs.truncate(budget);
    errs.truncate(budget);
    (gs, errs)
}

fn dithered_schemes() -> Vec<Scheme> {
    vec![
        Scheme::Dithered { delta: 1.0 },
        Scheme::Dithered { delta: 0.5 },
        Scheme::Dithered { delta: 1.0 / 3.0 },
        Scheme::DitheredPartitioned { delta: 0.5, k: 8 },
        Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
    ]
}

// ---- claim 1: the error is uniform on [-Δ/2, Δ/2] ---------------------------

#[test]
fn error_cdf_is_uniform_ks() {
    for scheme in dithered_schemes() {
        let delta = delta_of(&scheme) as f64;
        let (_, mut errs) = error_samples(scheme, 0xA11CE);
        let n = errs.len();
        assert!(n >= 100_000, "budget too small for the KS band");
        // support check first: Thm. 1 bounds the error pointwise
        let tol = 1e-4 * delta;
        assert!(
            errs.iter().all(|e| e.abs() <= delta / 2.0 + tol),
            "{scheme:?}: error escaped [-Δ/2, Δ/2]"
        );
        let d = ks_statistic_uniform(&mut errs, -delta / 2.0, delta / 2.0);
        // conservative acceptance band (~alpha = 5e-4): a genuinely
        // non-uniform error (e.g. QSGD's) lands an order of magnitude above
        let band = 1.95 / (n as f64).sqrt();
        assert!(
            d < band,
            "{scheme:?}: KS statistic {d:.5} outside the uniform band {band:.5}"
        );
    }
}

// ---- claim 2: the error is independent of the input -------------------------

/// Split per-element error variance by |g| halves (below/above median).
fn variance_by_magnitude(gs: &[f64], errs: &[f64]) -> (f64, f64) {
    let mut mags: Vec<f64> = gs.iter().map(|g| g.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = mags[mags.len() / 2];
    let (mut lo, mut hi) = ((0f64, 0usize), (0f64, 0usize));
    for (&g, &e) in gs.iter().zip(errs) {
        if g.abs() < median {
            lo = (lo.0 + e * e, lo.1 + 1);
        } else {
            hi = (hi.0 + e * e, hi.1 + 1);
        }
    }
    (lo.0 / lo.1 as f64, hi.0 / hi.1 as f64)
}

#[test]
fn error_uncorrelated_with_gradient() {
    for scheme in dithered_schemes() {
        let (gs, errs) = error_samples(scheme, 0xBEA7);
        let n = gs.len() as f64;
        let r = pearson(&gs, &errs);
        // 99.9% band for the sample correlation of independent pairs
        let band = 3.3 / n.sqrt();
        assert!(
            r.abs() < band.max(0.01),
            "{scheme:?}: corr(g, err) = {r:.5} — error depends on the input"
        );
        // second moment: conditional variance flat across |g|
        let (lo, hi) = variance_by_magnitude(&gs, &errs);
        let ratio = lo / hi;
        assert!(
            (0.9..=1.1).contains(&ratio),
            "{scheme:?}: var(err | small g)/var(err | large g) = {ratio:.3}"
        );
    }
}

#[test]
fn qsgd_error_depends_on_input_unlike_dqsg() {
    // the contrast claim: QSGD's stochastic-rounding error variance grows
    // with |g| (zero at grid points, maximal mid-bin) — dithering removes
    // exactly this input-dependence
    let budget = sample_budget();
    let mut rng = Xoshiro256::new(0xC0417);
    let mut q = Scheme::Qsgd { m: 1 }.build();
    let stream = DitherStream::new(5, 0);
    let (mut gs, mut errs) = (Vec::new(), Vec::new());
    let mut round = 0u64;
    while gs.len() < budget {
        let g: Vec<f32> = (0..CHUNK).map(|_| rng.next_normal() * 0.25).collect();
        let msg = q.encode(&g, &mut stream.round(round));
        let recon = q.decode(&msg, &mut stream.round(round), None).unwrap();
        let kappa = msg.scales().unwrap()[0];
        for (&gi, &ri) in g.iter().zip(&recon) {
            gs.push(gi as f64);
            errs.push((ri - gi) as f64 / kappa as f64);
        }
        round += 1;
    }
    let (lo, hi) = variance_by_magnitude(&gs, &errs);
    assert!(
        lo / hi < 0.6,
        "QSGD conditional variance ratio {:.3} — expected strong |g| dependence",
        lo / hi
    );
}

// ---- claim 3: per-element variance ≤ Δ²/12 ----------------------------------

#[test]
fn error_variance_within_delta_sq_over_12() {
    for scheme in dithered_schemes() {
        let delta = delta_of(&scheme) as f64;
        let (_, errs) = error_samples(scheme, 0x5EED);
        let n = errs.len() as f64;
        let mean = errs.iter().sum::<f64>() / n;
        let var = errs.iter().map(|e| (e - mean) * (e - mean)).sum::<f64>() / n;
        let bound = delta * delta / 12.0;
        assert!(
            var <= bound * 1.02,
            "{scheme:?}: var {var:.6} exceeds Δ²/12 = {bound:.6}"
        );
        assert!(
            var >= bound * 0.95,
            "{scheme:?}: var {var:.6} implausibly below Δ²/12 = {bound:.6} — \
             the dither is not exercising the full cell"
        );
        assert!(mean.abs() < 3.3 * (bound / n).sqrt() + 1e-6, "{scheme:?}: biased ({mean})");
    }
}

// ---- claim 4: NDQSG hits the DQSG bound at strictly fewer bits --------------

#[test]
fn ndqsg_matches_dqsg_variance_at_fewer_bits() {
    let d1 = 1.0f32 / 3.0;
    let nested = Scheme::Nested { d1, ratio: 3, alpha: 1.0 };
    let dqsg = Scheme::Dithered { delta: d1 };

    // (a) Thms. 5-6: equal error variance at the same fine step
    let (_, errs_n) = error_samples(nested, 0xF16);
    let (_, errs_d) = error_samples(dqsg, 0xF16 ^ 1);
    let var = |e: &[f64]| e.iter().map(|x| x * x).sum::<f64>() / e.len() as f64;
    let (vn, vd) = (var(&errs_n), var(&errs_d));
    let bound = (d1 as f64) * (d1 as f64) / 12.0;
    assert!(vn <= bound * 1.02, "NDQSG var {vn:.6} above the DQSG bound {bound:.6}");
    let ratio = vn / vd;
    assert!(
        (ratio - 1.0).abs() < 0.05,
        "NDQSG/DQSG variance ratio {ratio:.4} — Thm. 6 says 1 at alpha = 1"
    );

    // (b) the ledger: an NDQSG mix bills strictly fewer payload bits per
    // round than all-DQSG at the same fine step, with identical gradients
    let n = 30_000;
    let mut rng = Xoshiro256::new(33);
    let base: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.2).collect();
    let gs: Vec<Vec<f32>> = (0..4)
        .map(|_| base.iter().map(|&b| b + rng.next_normal() * 0.005).collect())
        .collect();
    let make = |schemes: &[Scheme]| -> Vec<WorkerMsg> {
        gs.iter()
            .enumerate()
            .map(|(p, g)| {
                let mut q = schemes[p].build();
                let stream = DitherStream::new(9, p as u32);
                WorkerMsg::new(p, 0, 0.0, q.encode(g, &mut stream.round(0)))
            })
            .collect()
    };
    let all_dqsg = vec![dqsg; 4];
    let mixed = vec![dqsg, dqsg, nested, nested];
    let mut s_dqsg = Session::new(&all_dqsg, 9, n).unwrap();
    s_dqsg.decode_round(&make(&all_dqsg)).unwrap();
    let mut s_mixed = Session::new(&mixed, 9, n).unwrap();
    s_mixed.decode_round(&make(&mixed)).unwrap();
    let (bits_dqsg, bits_mixed) = (
        s_dqsg.stats().total_raw_bits,
        s_mixed.stats().total_raw_bits,
    );
    assert!(
        bits_mixed < bits_dqsg,
        "mixed round {bits_mixed} bits !< all-DQSG round {bits_dqsg} bits"
    );
    // per-coordinate rates: log2(3) vs log2(7) ⇒ the mixed round saves
    // ~2 × (log2 7 - log2 3) / (4 log2 7) ≈ 21% — require at least 15%
    assert!(
        bits_mixed < bits_dqsg * 0.85,
        "saving too small: {bits_mixed} vs {bits_dqsg}"
    );
}
