//! End-to-end tests of the `ndq lint` static-analysis pass.
//!
//! The corpus under `tests/lint_fixtures/` seeds exactly one kind of
//! violation per rule (plus clean counterparts and directive-error cases);
//! every expectation pins the exact rule name *and* line so a drifting
//! lexer or engine shows up as a precise diff, not a flaky count. The
//! final tests gate the repo itself: the crate's own `src/` tree must stay
//! diagnostic-free, and the CLI must fail loudly on a seeded violation.

use ndq::lint::{lint_paths, lint_source, RULES};

fn manifest(rel: &str) -> String {
    format!("{}/{rel}", env!("CARGO_MANIFEST_DIR"))
}

/// Lint one fixture, reduced to (rule, line) pairs in reporting order.
fn diags_of(name: &str) -> Vec<(&'static str, u32)> {
    let path = manifest(&format!("tests/lint_fixtures/{name}"));
    let src = std::fs::read_to_string(&path).expect("fixture readable");
    lint_source(&path, &src).into_iter().map(|d| (d.rule, d.line)).collect()
}

#[test]
fn every_rule_fires_at_its_seeded_line() {
    assert_eq!(diags_of("wall_clock_bad.rs"), [("wall-clock", 5)]);
    assert_eq!(
        diags_of("unordered_iter_bad.rs"),
        [("unordered-iter", 3), ("unordered-iter", 5), ("unordered-iter", 6)]
    );
    assert_eq!(diags_of("float_cmp_bad.rs"), [("float-cmp", 5), ("float-cmp", 10)]);
    // line 6 carries both a slice-index and a `.unwrap` finding
    assert_eq!(
        diags_of("panic_path_bad.rs"),
        [("panic-path", 5), ("panic-path", 6), ("panic-path", 6)]
    );
    assert_eq!(diags_of("alloc_in_decode_bad.rs"), [("alloc-in-decode", 5)]);
    // `fill_*` chunk kernels are held to the same buffer-reuse contract,
    // including in src/prng/ (the dither fill path)
    assert_eq!(diags_of("alloc_in_fill_bad.rs"), [("alloc-in-decode", 6)]);
    // `*_ef` encode lanes (error-feedback carries) are on the same hot path
    assert_eq!(diags_of("alloc_in_ef_bad.rs"), [("alloc-in-decode", 6)]);
    assert_eq!(diags_of("naked_cast_bad.rs"), [("naked-cast", 5)]);
    assert_eq!(diags_of("unsafe_bad.rs"), [("unsafe-code", 4)]);
}

#[test]
fn clean_counterparts_stay_clean() {
    for f in ["clean_decode.rs", "clean_determinism.rs"] {
        let d = diags_of(f);
        assert!(d.is_empty(), "{f}: {d:?}");
    }
}

#[test]
fn reasonless_unknown_and_malformed_allows_are_rejected() {
    assert_eq!(diags_of("bad_allow.rs"), [("bad-allow", 4), ("bad-allow", 8), ("bad-allow", 13)]);
}

#[test]
fn stale_allows_are_flagged() {
    assert_eq!(diags_of("unused_allow.rs"), [("unused-allow", 4)]);
}

#[test]
fn reasoned_allows_cover_all_four_placements() {
    // trailing, own-line, fn-header and above-attribute-cluster allows
    // each suppress their seeded violation — and none is reported stale
    let d = diags_of("allowed_ok.rs");
    assert!(d.is_empty(), "{d:?}");
}

#[test]
fn cfg_test_items_are_elided() {
    let d = diags_of("elided_test_code.rs");
    assert!(d.is_empty(), "{d:?}");
}

#[test]
fn string_continuations_do_not_shift_line_numbers() {
    // the fixture's `\`-escaped newline inside a string spans two source
    // lines; the cast after it must still report line 9, not 8
    assert_eq!(diags_of("line_numbers.rs"), [("naked-cast", 9)]);
}

#[test]
fn repo_src_tree_is_lint_clean() {
    let report = lint_paths(&[manifest("src")]).expect("src tree lints");
    assert!(report.files >= 50, "only {} files seen", report.files);
    assert!(
        report.diags.is_empty(),
        "src tree has lint diagnostics:\n{}",
        report
            .diags
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn cli_fails_on_violations_and_passes_the_repo() {
    let bin = env!("CARGO_BIN_EXE_ndq");
    let bad = std::process::Command::new(bin)
        .arg("lint")
        .arg(manifest("tests/lint_fixtures/naked_cast_bad.rs"))
        .output()
        .expect("spawn ndq lint");
    assert!(!bad.status.success(), "seeded violation must fail the gate");
    let stdout = String::from_utf8_lossy(&bad.stdout);
    assert!(stdout.contains("naked_cast_bad.rs:5: naked-cast:"), "{stdout}");
    let stderr = String::from_utf8_lossy(&bad.stderr);
    assert!(stderr.contains("1 diagnostic(s)"), "{stderr}");

    let clean = std::process::Command::new(bin)
        .arg("lint")
        .arg(manifest("src"))
        .output()
        .expect("spawn ndq lint");
    assert!(clean.status.success(), "repo src must lint clean");

    let rules = std::process::Command::new(bin)
        .arg("lint")
        .arg("--rules")
        .output()
        .expect("spawn ndq lint --rules");
    assert!(rules.status.success());
    let listing = String::from_utf8_lossy(&rules.stdout);
    for r in RULES {
        assert!(listing.contains(r.name), "--rules listing missing {}", r.name);
    }
}
