//! Integration: wire-format contracts across schemes — the messages that
//! cross the worker->server channel survive byte-level serialization, the
//! shared-seed dither contract holds across independently-constructed
//! endpoints, and the coding layer meets the paper's "within 5% of entropy"
//! claim on *real training* gradients.

use std::sync::Arc;

use ndq::coding::entropy::Histogram;
use ndq::data::{Batch, ImageDataset, ImageKind};
use ndq::prng::{DitherStream, Xoshiro256};
use ndq::quant::{GradQuantizer, Scheme, WireMsg};
use ndq::runtime::{ComputeService, Manifest};
use ndq::testing::{gens, prop_check};

/// Simulate a real transport: ship the framed wire-v2 bytes and parse them
/// back on the receiver side. The receiver reconstructs everything —
/// scheme, frame directory, payload — from the byte stream alone.
fn through_the_wire(msg: &WireMsg) -> WireMsg {
    let bytes = msg.bytes().to_vec(); // what the socket carries
    WireMsg::parse(bytes).expect("framed message must re-parse")
}

#[test]
fn all_schemes_survive_byte_framing() {
    let mut rng = Xoshiro256::new(0);
    let g: Vec<f32> = (0..4321).map(|_| rng.next_normal() * 0.2).collect();
    let y: Vec<f32> = g.iter().map(|&x| x + 0.005 * rng.next_normal()).collect();
    for scheme in [
        Scheme::Baseline,
        Scheme::Dithered { delta: 1.0 },
        Scheme::Dithered { delta: 0.25 },
        Scheme::DitheredPartitioned { delta: 0.5, k: 7 },
        Scheme::Qsgd { m: 2 },
        Scheme::Terngrad,
        Scheme::OneBit,
        Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
    ] {
        let mut enc = scheme.build();
        let worker_stream = DitherStream::new(55, 9);
        let msg = enc.encode(&g, &mut worker_stream.round(123));
        let framed = through_the_wire(&msg);

        // fresh decoder + fresh server-side stream copy: only wire bytes +
        // shared seed cross the boundary
        let dec = scheme.build();
        let server_stream = DitherStream::new(55, 9);
        let side = if dec.needs_side_info() { Some(&y[..]) } else { None };
        let direct = dec
            .decode(&msg, &mut server_stream.round(123), side)
            .unwrap();
        let via_frame = dec
            .decode(&framed, &mut server_stream.round(123), side)
            .unwrap();
        assert_eq!(direct, via_frame, "{scheme:?} framed decode differs");
    }
}

#[test]
fn prop_wire_roundtrip_random_gradients() {
    prop_check(
        "wire-roundtrip",
        40,
        gens::pair(gens::nasty_f32_vec(2000), gens::seed()),
        |(g, seed)| {
            for scheme in [
                Scheme::Dithered { delta: 1.0 },
                Scheme::Qsgd { m: 1 },
                Scheme::OneBit,
            ] {
                let mut enc = scheme.build();
                let ws = DitherStream::new(*seed, 0);
                let msg = enc.encode(g, &mut ws.round(7));
                let framed = through_the_wire(&msg);
                let dec = scheme.build();
                let ss = DitherStream::new(*seed, 0);
                let out = dec
                    .decode(&framed, &mut ss.round(7), None)
                    .map_err(|e| e.to_string())?;
                if out.len() != g.len() {
                    return Err(format!("{scheme:?}: len {} != {}", out.len(), g.len()));
                }
                if !out.iter().all(|v| v.is_finite()) {
                    return Err(format!("{scheme:?}: non-finite reconstruction"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn aac_within_5pct_of_entropy_on_real_gradients() {
    // the paper's §4 claim, checked on an actual model gradient
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    let svc = ComputeService::start(std::path::Path::new("artifacts")).unwrap();
    let h = svc.handle();
    let m = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let params = Arc::new(m.init_params("fc300").unwrap());
    let ds = ImageDataset::new(ImageKind::Mnist, 0);
    let mut batch = Batch::new(32, 784);
    ds.train_batch(0, 0, 1, 32, &mut batch);
    let (_, grad) = h
        .grad_image("fc300", &params, batch.x, batch.y, 32)
        .unwrap();

    for scheme in [Scheme::Dithered { delta: 1.0 }, Scheme::Qsgd { m: 1 }, Scheme::Terngrad] {
        let mut q = scheme.build();
        let stream = DitherStream::new(0, 0);
        let msg = q.encode(&grad, &mut stream.round(0));
        let h_bits = msg.entropy_bits();
        let aac_bits = msg.aac_bits() as f64;
        let ratio = aac_bits / h_bits;
        assert!(
            ratio < 1.05,
            "{scheme:?}: AAC {aac_bits:.0} vs entropy {h_bits:.0} (ratio {ratio:.4})"
        );
    }
}

#[test]
fn index_distribution_is_peaked_at_zero_on_real_gradients() {
    // what makes Table 2 << Table 1: most ternary indices are 0
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    let svc = ComputeService::start(std::path::Path::new("artifacts")).unwrap();
    let h = svc.handle();
    let m = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let params = Arc::new(m.init_params("fc300").unwrap());
    let ds = ImageDataset::new(ImageKind::Mnist, 0);
    let mut batch = Batch::new(32, 784);
    ds.train_batch(0, 0, 1, 32, &mut batch);
    let (_, grad) = h
        .grad_image("fc300", &params, batch.x, batch.y, 32)
        .unwrap();
    let mut q = Scheme::Dithered { delta: 1.0 }.build();
    let stream = DitherStream::new(0, 0);
    let msg = q.encode(&grad, &mut stream.round(0));
    let sym: Vec<u32> = msg
        .indices()
        .unwrap()
        .iter()
        .map(|&v| (v + 1) as u32)
        .collect();
    let hist = Histogram::from_symbols(&sym, 3);
    assert!(hist.prob(1) > 0.5, "P(index=0) = {}", hist.prob(1));
    assert!(hist.entropy_bits() < 1.58);
}
