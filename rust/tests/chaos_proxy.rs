//! Chaos-proxy acceptance: real socket misbehavior must land in the same
//! ledger classes the virtual [`FaultChannel`] model predicts.
//!
//! A byte-level proxy sits between one worker and the leader and injects
//! two real transport faults:
//!
//! * **delay** — it holds one round's uplink past the leader's sweep
//!   valve, so the leader gives up on the round (a zero-bit `Drop` entry)
//!   and then meets the stale frame next round (a `late` entry);
//! * **disconnect** — mid-run it tears both connections down without a
//!   `Bye`, which the leader must bill as a first-class `Disconnect`.
//!
//! The twin run replays the same story through the *virtual* fault plan
//! (`drop_at` + `delay_at` + `disconnect_at`) on the in-process harness.
//! Byte counts legitimately differ (the virtual drop bills the message's
//! real bits; the valve drop bills zero because no bytes ever arrived),
//! so the contract is **class counts**: dropped, late, and disconnect
//! entries match one-for-one.

use std::io::{Read, Write};
use std::path::PathBuf;
use std::time::Duration;

use ndq::comm::net::{
    append_envelope, FrameAccum, FramePoll, NetAddr, NetListener, NetStream, NET_KIND_GRAD,
};
use ndq::comm::{FaultPlan, RoundPolicy};
use ndq::testing::cluster::{
    run_scenario, serve_listener, worker_connect, ClusterScenario, ServeOptions,
};

/// A collision-free socket path in the test tempdir.
fn uds_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ndq-{}-{tag}.sock", std::process::id()))
}

const DELAY_ROUND: usize = 2;
const DISCONNECT_ROUND: usize = 6;
/// Must exceed the leader's sweep valve (so the delayed frame misses its
/// round) but stay under two valves (so it lands in the *next* round).
const PROXY_DELAY: Duration = Duration::from_secs(3);
const VALVE: Duration = Duration::from_secs(2);

/// Forward framed envelopes front -> back, delaying the `DELAY_ROUND`-th
/// gradient and vanishing at the `DISCONNECT_ROUND`-th.
fn uplink_shuttle(mut front: NetStream, back: NetStream) {
    let mut accum = FrameAccum::new();
    let mut out: Vec<u8> = Vec::new();
    let mut grads = 0usize;
    let mut back_w = back;
    loop {
        match accum.poll_frame(&mut front) {
            Ok(FramePoll::Ready) => {
                let is_grad = {
                    let (kind, _) = accum.frame();
                    kind == NET_KIND_GRAD
                };
                if is_grad && grads == DISCONNECT_ROUND {
                    front.shutdown();
                    back_w.shutdown();
                    return;
                }
                if is_grad && grads == DELAY_ROUND {
                    std::thread::sleep(PROXY_DELAY);
                }
                out.clear();
                {
                    let (kind, body) = accum.frame();
                    append_envelope(&mut out, kind, body).expect("re-frame");
                }
                if back_w.write_all(&out).is_err() {
                    return;
                }
                accum.consume();
                grads += usize::from(is_grad);
            }
            Ok(FramePoll::Pending) => continue,
            Ok(FramePoll::Eof) | Err(_) => {
                back_w.shutdown();
                return;
            }
        }
    }
}

/// Copy raw downlink bytes back -> front until either side closes.
fn downlink_shuttle(mut back: NetStream, mut front: NetStream) {
    let mut buf = [0u8; 16 * 1024];
    loop {
        match back.read(&mut buf) {
            Ok(0) => {
                front.shutdown();
                return;
            }
            Ok(n) => {
                if front.write_all(&buf[..n]).is_err() {
                    return;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                front.shutdown();
                return;
            }
        }
    }
}

fn scenario(plan: FaultPlan) -> ClusterScenario {
    ClusterScenario {
        workers: 3,
        n_params: 400,
        rounds: 10,
        policy: RoundPolicy::Quorum(2),
        eval_every: 5,
        plan,
        ..ClusterScenario::default()
    }
}

#[test]
fn proxy_chaos_bills_like_the_virtual_fault_model() {
    // --- the real run: leader + 2 direct workers + 1 proxied worker ----
    let back_addr = NetAddr::Uds(uds_path("chaos-back"));
    let listener = NetListener::bind(&back_addr).unwrap();
    let dial_back = listener.local_addr().unwrap();
    let front_addr = NetAddr::Uds(uds_path("chaos-front"));
    let front_listener = NetListener::bind(&front_addr).unwrap();

    let proxy = {
        let dial_back = dial_back.clone();
        std::thread::spawn(move || {
            let front = front_listener.accept().expect("proxy accept");
            let back = NetStream::connect_retry(&dial_back, Duration::from_secs(10))
                .expect("proxy dial leader");
            let up = {
                let front_r = front.try_clone().expect("clone front");
                let back_w = back.try_clone().expect("clone back");
                std::thread::spawn(move || uplink_shuttle(front_r, back_w))
            };
            downlink_shuttle(back, front);
            up.join().expect("uplink shuttle panicked");
        })
    };

    let direct: Vec<_> = (0..2)
        .map(|_| {
            let dial = dial_back.clone();
            std::thread::spawn(move || worker_connect(&dial, Duration::from_secs(10)))
        })
        .collect();
    let proxied = std::thread::spawn(move || {
        worker_connect(&front_addr, Duration::from_secs(10))
    });

    let got = serve_listener(
        scenario(FaultPlan::new()),
        listener,
        ServeOptions { io_timeout: VALVE },
    )
    .unwrap();

    for p in direct {
        p.join().expect("worker thread panicked").unwrap();
    }
    // the proxied worker loses its connection mid-run: it must error out,
    // not hang
    assert!(proxied.join().expect("proxied worker panicked").is_err());
    proxy.join().expect("proxy panicked");

    // --- the virtual twin: same story, scripted through FaultChannel ---
    let want = run_scenario(scenario(
        FaultPlan::new()
            .drop_at(0, DELAY_ROUND)
            .delay_at(0, DELAY_ROUND + 1, 1)
            .disconnect_at(0, DISCONNECT_ROUND),
    ))
    .unwrap();

    // class-for-class ledger parity
    assert_eq!(got.comm.dropped_msgs, want.comm.dropped_msgs);
    assert_eq!(got.comm.late_msgs, want.comm.late_msgs);
    assert_eq!(got.comm.disconnects, want.comm.disconnects);
    assert_eq!(got.comm.dropped_msgs, 1, "valve miss bills exactly one drop");
    assert_eq!(got.comm.late_msgs, 1, "stale frame bills exactly one late");
    assert_eq!(got.comm.disconnects, 1);
    // the valve drop is zero-bit: nothing arrived, nothing to bill
    assert_eq!(got.comm.dropped_bits, 0);

    // quorum absorbed all of it, on both transports
    assert_eq!(got.rounds_failed, 0);
    assert_eq!(want.rounds_failed, 0);
    assert!(got.final_eval_loss.is_finite());
    // after the disconnect every surviving round hears the two direct
    // workers
    assert!(got
        .delivery
        .iter()
        .skip(DISCONNECT_ROUND)
        .all(|d| d.received == 2), "{:?}", got.delivery);
}
