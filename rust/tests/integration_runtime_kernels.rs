//! Integration: the standalone L1 kernel artifacts (Pallas, lowered to HLO)
//! executed through the rust PJRT runtime must agree with the rust-native
//! quantizer implementations — the L1 <-> L3 consistency contract.

use ndq::prng::{DitherStream, Xoshiro256};
use ndq::quant::{GradQuantizer, Scheme};
use ndq::runtime::{ComputeService, Manifest, RawArg, RawOut};

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

const N: usize = 266_610; // fc300 n_params — the size the kernels were lowered at

#[test]
fn pjrt_quantize_kernel_matches_rust_native() {
    if !have_artifacts() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    let svc = ComputeService::start(std::path::Path::new("artifacts")).unwrap();
    let h = svc.handle();
    let mut rng = Xoshiro256::new(42);
    let g: Vec<f32> = (0..N).map(|_| rng.next_normal() * 0.1).collect();
    // dither from the shared stream — identical for both paths
    let mut u = vec![0f32; N];
    DitherStream::new(9, 0).round(0).fill_dither(0.5, &mut u);

    // PJRT path: the Pallas dq_quantize kernel (delta = 1.0 baked at AOT)
    let outs = h
        .exec_raw(
            &format!("quantize_dq_{N}"),
            vec![
                RawArg::F32(g.clone(), vec![N as i64]),
                RawArg::F32(u.clone(), vec![N as i64]),
            ],
        )
        .unwrap();
    let (q_pjrt, kappa_pjrt) = match (&outs[0], &outs[1]) {
        (RawOut::I32(q), RawOut::F32(k)) => (q.clone(), k[0]),
        other => panic!("unexpected output kinds: {other:?}"),
    };

    // rust-native path with the same dither
    let kappa = ndq::tensor::linf_norm(&g);
    assert!((kappa - kappa_pjrt).abs() <= 1e-6 * kappa, "{kappa} vs {kappa_pjrt}");
    let mut mismatches = 0usize;
    for i in 0..N {
        let t = g[i] / kappa + u[i];
        let q = (t.round() as i32).clamp(-1, 1);
        if q != q_pjrt[i] {
            mismatches += 1;
        }
    }
    // identical math up to f32 associativity at exact bin edges
    assert!(
        mismatches <= 2,
        "{mismatches} index mismatches between Pallas kernel and rust-native"
    );
}

#[test]
fn pjrt_nested_kernels_roundtrip_with_rust_decode() {
    if !have_artifacts() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    let svc = ComputeService::start(std::path::Path::new("artifacts")).unwrap();
    let h = svc.handle();
    let (d1, _ratio, alpha) = (1.0f32 / 3.0, 3u32, 1.0f32);
    let mut rng = Xoshiro256::new(1);
    // kappa=1 convention: kernels operate on normalized gradients
    let g: Vec<f32> = (0..N).map(|_| (rng.next_normal() * 0.2).clamp(-1.0, 1.0)).collect();
    let y: Vec<f32> = g.iter().map(|&x| x + rng.next_normal() * 0.02).collect();
    let mut u = vec![0f32; N];
    DitherStream::new(3, 0).round(0).fill_dither(d1 / 2.0, &mut u);

    let enc = h
        .exec_raw(
            &format!("nested_enc_{N}"),
            vec![
                RawArg::F32(g.clone(), vec![N as i64]),
                RawArg::F32(u.clone(), vec![N as i64]),
            ],
        )
        .unwrap();
    let s = match &enc[0] {
        RawOut::I32(s) => s.clone(),
        other => panic!("{other:?}"),
    };
    assert!(s.iter().all(|&v| (-1..=1).contains(&v)));

    let dec = h
        .exec_raw(
            &format!("nested_dec_{N}"),
            vec![
                RawArg::I32(s, vec![N as i64]),
                RawArg::F32(u, vec![N as i64]),
                RawArg::F32(y, vec![N as i64]),
            ],
        )
        .unwrap();
    let xh = match &dec[0] {
        RawOut::F32(x) => x.clone(),
        other => panic!("{other:?}"),
    };
    // exact decoding regime: |error| <= alpha * d1 / 2
    let mut bad = 0usize;
    for (a, b) in g.iter().zip(&xh) {
        if (a - b).abs() > alpha * d1 / 2.0 + 1e-5 {
            bad += 1;
        }
    }
    assert!(
        (bad as f64) < 0.001 * N as f64,
        "{bad}/{N} coordinates outside the Thm.-6 exact-decode bound"
    );
}

#[test]
fn pjrt_dequant_avg_matches_rust_server() {
    if !have_artifacts() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    let svc = ComputeService::start(std::path::Path::new("artifacts")).unwrap();
    let h = svc.handle();
    let p = 4usize;
    let delta = 1.0f32;
    let mut rng = Xoshiro256::new(5);
    // build P encoded workers with rust, decode with the PJRT kernel
    let mut qs = Vec::with_capacity(p * N);
    let mut us = Vec::with_capacity(p * N);
    let mut kappas = Vec::with_capacity(p);
    let mut rust_avg = vec![0f32; N];
    for worker in 0..p {
        let g: Vec<f32> = (0..N).map(|_| rng.next_normal() * 0.1).collect();
        let mut q = Scheme::Dithered { delta }.build();
        let stream = DitherStream::new(77, worker as u32);
        let msg = q.encode(&g, &mut stream.round(0));
        let recon = q.decode(&msg, &mut stream.round(0), None).unwrap();
        ndq::tensor::axpy(1.0 / p as f32, &recon, &mut rust_avg);
        let mut u = vec![0f32; N];
        stream.round(0).fill_dither(delta / 2.0, &mut u);
        qs.extend_from_slice(&msg.indices().unwrap());
        us.extend_from_slice(&u);
        kappas.push(msg.scales().unwrap()[0]);
    }
    let outs = h
        .exec_raw(
            &format!("dequant_avg_{N}_p{p}"),
            vec![
                RawArg::I32(qs, vec![p as i64, N as i64]),
                RawArg::F32(us, vec![p as i64, N as i64]),
                RawArg::F32(kappas, vec![p as i64]),
            ],
        )
        .unwrap();
    let pjrt_avg = match &outs[0] {
        RawOut::F32(v) => v.clone(),
        other => panic!("{other:?}"),
    };
    let rmse = (ndq::tensor::sq_dist(&rust_avg, &pjrt_avg) / N as f64).sqrt();
    assert!(rmse < 1e-6, "PJRT vs rust server aggregation rmse {rmse}");
}
