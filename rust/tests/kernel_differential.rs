//! Differential fuzz: the monomorphized decode kernels against the
//! generic interpreters they replaced.
//!
//! The specialization contract is *bit-identity*: for every (scheme, k,
//! codec) cell the fast path must produce the same wire bytes, the same
//! [`BitMetrics`], and the same reconstruction — down to the f32 bit
//! pattern — as the per-symbol oracle, under arbitrary tensors and
//! arbitrary chunk segmentations. Any divergence here would silently
//! change run fingerprints, so these properties gate tier-1.

use ndq::coding::{
    arithmetic, huffman, pack, BitReader, BitWriter, KernelMode, KernelPlan, SymbolSource,
};
use ndq::prng::{DitherStream, Xoshiro256};
use ndq::quant::{GradQuantizer, PayloadCodec, Scheme};
use ndq::testing::{gens, prop_check};

const CODECS: [PayloadCodec; 3] = [PayloadCodec::Raw, PayloadCodec::Huffman, PayloadCodec::Aac];

/// Alphabets covering every monomorphized raw kernel (pow2 at 2/4/8/16,
/// the const-divisor family at 3/5/7/9/15) plus generic fallbacks (17, 21).
const KS: [u32; 11] = [2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 21];

/// Every index-lane scheme, chosen so each raw kernel family and the
/// in-plan generic fallback all appear (see `kernel_plans_resolve_per_scheme`).
const SCHEMES: [Scheme; 10] = [
    Scheme::Dithered { delta: 1.0 },                  // k3
    Scheme::Terngrad,                                 // k3
    Scheme::Qsgd { m: 2 },                            // k5
    Scheme::Dithered { delta: 1.0 / 3.0 },            // k7
    Scheme::Nested { d1: 0.2, ratio: 9, alpha: 1.0 }, // k9 + side info
    Scheme::Qsgd { m: 7 },                            // k15
    Scheme::DitheredPartitioned { delta: 1.0, k: 4 }, // k3 through partition bounds
    Scheme::Qsgd { m: 10 },                           // k21: generic fallback in-plan
    Scheme::Nuqsgd { m: 2 },                          // k5, log level table
    Scheme::Nuqsgd { m: 7 },                          // k15, log level table
];

/// Drain `n` symbols through `mode`'s kernel in randomly sized chunks.
fn drain_segmented(
    src: &mut SymbolSource<'_, '_>,
    mode: KernelMode,
    n: usize,
    rng: &mut Xoshiro256,
) -> Result<Vec<u32>, String> {
    let mut out = vec![0u32; n];
    let mut off = 0usize;
    while off < n {
        let take = (1 + rng.next_below(320) as usize).min(n - off);
        src.fill(mode, &mut out[off..off + take]).map_err(|e| e.to_string())?;
        off += take;
    }
    Ok(out)
}

#[test]
fn chunked_symbol_kernels_match_generic_oracle_for_every_cell() {
    prop_check("symbol-kernel-differential", 24, gens::seed(), |&seed| {
        let mut rng = Xoshiro256::new(seed);
        let n = 1 + rng.next_below(700) as usize;
        for &k in &KS {
            let symbols: Vec<u32> = (0..n).map(|_| rng.next_below(k)).collect();
            for codec in CODECS {
                let mut w = BitWriter::new();
                match codec {
                    PayloadCodec::Raw => pack::pack_base_k(&symbols, k, &mut w),
                    PayloadCodec::Huffman => huffman::encode(&symbols, k as usize, &mut w),
                    PayloadCodec::Aac => arithmetic::encode(&symbols, k as usize, &mut w),
                }
                let bytes = w.into_bytes();
                let plan = KernelPlan::specialized(k);
                let cell = format!("k={k} codec={} n={n}", codec.label());

                let mut rs = BitReader::new(&bytes);
                let mut ss = SymbolSource::with_plan(&mut rs, codec, k, n, plan)
                    .map_err(|e| format!("{cell}: {e}"))?;
                let spec = drain_segmented(&mut ss, KernelMode::Specialized, n, &mut rng)
                    .map_err(|e| format!("{cell}: {e}"))?;

                let mut rg = BitReader::new(&bytes);
                let mut sg = SymbolSource::with_plan(&mut rg, codec, k, n, plan)
                    .map_err(|e| format!("{cell}: {e}"))?;
                let oracle = drain_segmented(&mut sg, KernelMode::Generic, n, &mut rng)
                    .map_err(|e| format!("{cell}: {e}"))?;

                if oracle != symbols {
                    return Err(format!("{cell}: generic oracle broke the roundtrip"));
                }
                if spec != oracle {
                    let at = spec.iter().zip(&oracle).position(|(a, b)| a != b);
                    return Err(format!("{cell}: specialized diverges at index {at:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn quantizer_decode_is_mode_invariant_for_every_scheme_and_codec() {
    let tensors = gens::pair(gens::f32_vec(600), gens::seed());
    prop_check("kernel-mode-differential", 12, tensors, |(g, seed)| {
        // side info for the nested decoder: any vector of matching length
        let y: Vec<f32> = g.iter().map(|&x| x * 0.9 + 0.01).collect();
        for scheme in SCHEMES {
            for codec in CODECS {
                let cell =
                    format!("scheme={} codec={} n={}", scheme.label(), codec.label(), g.len());
                let mut qs = scheme.build_with_mode(KernelMode::Specialized);
                let mut qg = scheme.build_with_mode(KernelMode::Generic);
                let stream = DitherStream::new(*seed, 0);

                // encode never depends on the kernel mode: the wire bytes
                // and the encode-time metrics must be byte-for-byte equal
                let ms = qs.encode_coded(g, &mut stream.round(0), codec);
                let mg = qg.encode_coded(g, &mut stream.round(0), codec);
                if ms.bytes() != mg.bytes() {
                    return Err(format!("{cell}: encode bytes differ across kernel modes"));
                }
                if ms.carried_metrics() != mg.carried_metrics() {
                    return Err(format!("{cell}: BitMetrics differ across kernel modes"));
                }

                // decode the same message through both kernels: bit-equal
                let side = if scheme.needs_side_info() { Some(&y[..]) } else { None };
                let mut out_s = vec![0f32; g.len()];
                let mut out_g = vec![0f32; g.len()];
                qs.decode_into(&ms, &mut stream.round(0), side, &mut out_s)
                    .map_err(|e| format!("{cell}: specialized decode: {e}"))?;
                qg.decode_into(&ms, &mut stream.round(0), side, &mut out_g)
                    .map_err(|e| format!("{cell}: generic decode: {e}"))?;
                let diverged = out_s
                    .iter()
                    .zip(&out_g)
                    .position(|(a, b)| a.to_bits() != b.to_bits());
                if let Some(i) = diverged {
                    return Err(format!(
                        "{cell}: decode diverges at {i}: {} vs {}",
                        out_s[i], out_g[i]
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn huffman_fast_encode_matches_per_bit_oracle() {
    prop_check("huffman-encode-differential", 32, gens::seed(), |&seed| {
        let mut rng = Xoshiro256::new(seed);
        let m = 1 + rng.next_below(10) as i32;
        let n = 1 + rng.next_below(2000) as usize;
        let q: Vec<i32> = (0..n)
            .map(|_| rng.next_below((2 * m + 1) as u32) as i32 - m)
            .collect();
        let mut wf = BitWriter::new();
        huffman::encode_signed(&q, m, &mut wf);
        let mut wg = BitWriter::new();
        huffman::encode_signed_generic(&q, m, &mut wg);
        if wf.len_bits() != wg.len_bits() {
            return Err(format!(
                "m={m} n={n}: bit lengths differ ({} vs {})",
                wf.len_bits(),
                wg.len_bits()
            ));
        }
        if wf.into_bytes() != wg.into_bytes() {
            return Err(format!("m={m} n={n}: encoded bytes differ"));
        }
        Ok(())
    });
}
