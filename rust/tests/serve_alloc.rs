//! Allocation-regression gate for the leader's event-loop hot path.
//!
//! The PR-10 tentpole claims the serve leader reaches a steady state where
//! a round costs **zero heap allocations**: broadcast framing reuses two
//! persistent buffers, uplink reassembly lands in per-peer slabs, wire
//! parses run on the session's scratch pool, and the exchange/fold
//! bookkeeping cycles through session-owned pools. This test pins the
//! claim mechanically: a counting global allocator tallies allocations on
//! the leader thread for a short run and a 10-rounds-longer run of the
//! same scenario — if steady-state rounds are allocation-free the two
//! totals are *identical*, because everything else (handshake, warmup
//! rounds, report assembly, teardown) is round-count-invariant.
//!
//! The counter is a `const`-initialized `thread_local!` `Cell`, so reading
//! and bumping it never allocates (no lazy init, no destructor) and worker
//! threads don't pollute the leader's tally.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::path::PathBuf;
use std::time::Duration;

use ndq::comm::net::{NetAddr, NetListener};
use ndq::testing::cluster::{serve_listener, worker_connect, ClusterScenario, ServeOptions};
use ndq::train::TrainReport;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: defers every operation to `System`; the bookkeeping around it is
// a plain thread-local counter with no allocation and no reentrancy.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn uds_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ndq-{}-{tag}.sock", std::process::id()))
}

fn scenario(rounds: usize) -> ClusterScenario {
    ClusterScenario {
        workers: 4,
        n_params: 600,
        rounds,
        eval_every: 1,
        ..ClusterScenario::default()
    }
}

/// Serve `rounds` rounds over UDS with thread workers and return the
/// number of allocations the **leader thread** performed inside
/// [`serve_listener`], plus the report.
fn leader_allocs(rounds: usize, tag: &str) -> (u64, TrainReport) {
    let sc = scenario(rounds);
    let addr = NetAddr::Uds(uds_path(tag));
    let listener = NetListener::bind(&addr).unwrap();
    let dial = listener.local_addr().unwrap();
    let peers: Vec<_> = (0..sc.workers)
        .map(|_| {
            let dial = dial.clone();
            std::thread::spawn(move || worker_connect(&dial, Duration::from_secs(10)))
        })
        .collect();
    let opts = ServeOptions {
        io_timeout: Duration::from_secs(30),
    };
    let c0 = ALLOCS.with(|c| c.get());
    let report = serve_listener(sc, listener, opts);
    let c1 = ALLOCS.with(|c| c.get());
    for p in peers {
        p.join().expect("worker thread panicked").unwrap();
    }
    (c1 - c0, report.unwrap())
}

#[test]
fn steady_state_rounds_allocate_nothing_on_the_leader() {
    // 3 rounds of warmup margin: pools (wire scratch, decode buffers,
    // exchange state) all fill by the end of round 0, but the comparison
    // stays honest even if a pool warms a round or two later
    let (base, short) = leader_allocs(3, "alloc-base");
    let (long, full) = leader_allocs(13, "alloc-long");
    assert_eq!(short.rounds_failed, 0);
    assert_eq!(full.rounds_failed, 0);
    assert_eq!(short.delivery.len(), 3);
    assert_eq!(full.delivery.len(), 13);
    // identical totals <=> the 10 extra steady rounds performed zero heap
    // allocations on the leader thread
    assert_eq!(
        long, base,
        "leader hot loop allocated in steady-state rounds \
         (3-round run: {base} allocs, 13-round run: {long} allocs)"
    );
    // sanity: the counter is actually live (handshake + warmup allocate)
    assert!(base > 0, "counting allocator saw no allocations at all");
}
