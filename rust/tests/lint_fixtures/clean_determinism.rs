// ndq-lint: as(src/train/fixture.rs)
// clean counterpart: canonical containers and total float ordering

use std::collections::BTreeMap;

pub fn largest(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.total_cmp(b));
    xs[xs.len() - 1]
}

pub fn tally(xs: &[u32]) -> BTreeMap<u32, u32> {
    let mut m = BTreeMap::new();
    for &x in xs {
        *m.entry(x).or_insert(0) += 1;
    }
    m
}
