// ndq-lint: as(src/comm/net.rs)
// seeded panic-path violations inside a decode-marked function

pub fn decode_header(bytes: &[u8]) -> u32 {
    assert!(bytes.len() >= 4);
    let b: [u8; 4] = bytes[..4].try_into().unwrap();
    u32::from_le_bytes(b)
}

pub fn plain_first_byte(bytes: &[u8]) -> u8 {
    bytes[0]
}
