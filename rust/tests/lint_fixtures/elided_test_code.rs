// ndq-lint: as(src/comm/net.rs)
// test items may unwrap and index freely: the lint binds shipping code

pub fn decode_first(bytes: &[u8]) -> Option<u8> {
    bytes.first().copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwraps_and_indexes_fine() {
        assert_eq!(decode_first(&[7]).unwrap(), 7);
        let v = vec![1u8, 2];
        assert_eq!(v[0], 1);
    }
}
