// seeded stale allow: names a real rule but suppresses nothing

pub fn f() -> u32 {
    // ndq-lint: allow(wall-clock) pretending the next line reads a clock
    7
}
