// ndq-lint: as(src/comm/net.rs)
// lexer regression: the string continuation below escapes a newline; the
// violation after it must still report its true source line

pub const MSG: &str = "a continuation \
    spanning two source lines";

pub fn frame_len(total: u64) -> u32 {
    total as u32
}
