// ndq-lint: as(src/prng/fixture.rs)
// seeded alloc-in-decode violation: a `fill_*` chunk kernel that allocates
// (the dither/symbol fill loops must reuse caller-owned buffers)

pub fn fill_lanes(out: &mut [u32]) {
    let lanes: Vec<u32> = (0..out.len() as u32).collect();
    out.copy_from_slice(&lanes);
}
