// seeded directive errors: reasonless allow, unknown rule, malformed

pub fn f() -> u32 {
    // ndq-lint: allow(wall-clock)
    7
}

// ndq-lint: allow(no-such-rule) the rule name is not in the registry
pub fn g() -> u32 {
    8
}

// ndq-lint: frobnicate
pub fn h() -> u32 {
    9
}
