// ndq-lint: as(src/comm/net.rs)
// seeded naked-cast violation: bare narrowing on a length field

pub fn frame_len(total: u64) -> u32 {
    total as u32
}
