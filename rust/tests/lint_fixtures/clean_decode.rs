// ndq-lint: as(src/comm/net.rs)
// clean counterpart: checked conversions, get-based access, typed errors

pub fn decode_len(bytes: &[u8]) -> Result<usize, String> {
    let b = bytes.get(..4).ok_or_else(|| "truncated header".to_string())?;
    let mut raw = [0u8; 4];
    raw.copy_from_slice(b);
    usize::try_from(u32::from_le_bytes(raw)).map_err(|e| e.to_string())
}
