// seeded wall-clock violation (crate-wide rule)
use std::time::Instant;

pub fn elapsed_wrong() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}
