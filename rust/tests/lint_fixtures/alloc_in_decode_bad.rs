// ndq-lint: as(src/quant/fixture.rs)
// seeded alloc-in-decode violation: a `*_into` decoder that allocates

pub fn unpack_into(out: &mut Vec<u32>, n: usize) {
    let scratch = vec![0u32; n];
    out.extend_from_slice(&scratch);
}
