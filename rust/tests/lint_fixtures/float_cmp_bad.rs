// ndq-lint: as(src/stats/fixture.rs)
// seeded float-cmp violations: a partial_cmp sort and a float-literal ==

pub fn smallest(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[0]
}

pub fn is_zero(x: f32) -> bool {
    x == 0.0
}
