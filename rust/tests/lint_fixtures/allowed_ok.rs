// ndq-lint: as(src/comm/net.rs)
// clean-by-annotation: every seeded violation carries a reasoned allow,
// exercising all four placements (trailing, own-line, fn header, above
// an attribute cluster)

use std::time::Instant;

pub fn trailing(t0: Instant) -> f64 {
    let dt = Instant::now() - t0; // ndq-lint: allow(wall-clock) fixture: trailing placement
    dt.as_secs_f64()
}

pub fn own_line(total: u64) -> u32 {
    // ndq-lint: allow(naked-cast) fixture: own-line placement
    total as u32
}

// ndq-lint: allow(panic-path) fixture: fn-header placement covers the body
pub fn decode_both(bytes: &[u8]) -> u8 {
    let first = bytes[0];
    assert!(first < 128);
    first
}

// ndq-lint: allow(panic-path) fixture: placement above an attribute cluster
#[inline]
pub fn parse_first(bytes: &[u8]) -> u8 {
    bytes[0]
}
