// seeded unsafe-code violation (crate-wide rule; mirrors #![forbid(unsafe_code)])

pub fn first_byte(p: *const u8) -> u8 {
    unsafe { *p }
}
