// ndq-lint: as(src/quant/fixture.rs)
// seeded alloc-in-decode violation: a `*_ef` encode lane that allocates
// (error-feedback carries run every round and must reuse pooled scratch)

pub fn update_ef(lane: &mut Vec<f32>, v: &[f32]) {
    let fresh = vec![0f32; v.len()];
    lane.copy_from_slice(&fresh);
}
