#!/usr/bin/env python3
"""Golden wire-v3 fixture generator.

Bit-exact Python replica of the Rust encode pipeline (Philox4x32-10 dither,
f32 quantization, base-k packing, canonical-Huffman and adaptive-arithmetic
index-lane coding, wire-v3 framing with the payload-codec header byte,
CRC-32) used to produce the checked-in `.hex` snapshots that
`tests/wire_v2_conformance.rs` pins the byte layout against. Regenerate
with:

    python3 rust/tests/fixtures/wire_v2/generate.py

Every fixture encodes the same 8-element gradient with run_seed=7, worker=0,
round=0. Gradient values are chosen f32-exact with kappa = 1.0 so every
scale/divide below is an exact power-of-two operation; the remaining f32
adds/multiplies are IEEE-754 single ops replicated with numpy.float32.
"""

import binascii
import math
import struct
from pathlib import Path

import numpy as np

M64 = (1 << 64) - 1
M32 = (1 << 32) - 1

G = [0.25, -0.75, 0.5, -1.0, 0.0625, -0.125, 1.0, 0.375]
RUN_SEED, WORKER, ROUND = 7, 0, 0
OUT_DIR = Path(__file__).resolve().parent


# --- prng/philox.rs ---------------------------------------------------------

def splitmix64(x):
    x = (x + 0x9E3779B97F4A7C15) & M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & M64
    return x ^ (x >> 31)


class Philox:
    M0, M1 = 0xD2511F53, 0xCD9E8D57
    W0, W1 = 0x9E3779B9, 0xBB67AE85

    def __init__(self, run_seed, worker, rnd):
        k = splitmix64((run_seed ^ ((worker * 0xA24BAED4963EE407) & M64)) & M64)
        self.key = [k & M32, (k >> 32) & M32]
        c = (rnd & M64) << 64
        self.counter = [(c >> (32 * i)) & M32 for i in range(4)]

    def next_block(self):
        ctr, key = list(self.counter), list(self.key)
        for _ in range(10):
            p0 = self.M0 * ctr[0]
            hi0, lo0 = (p0 >> 32) & M32, p0 & M32
            p1 = self.M1 * ctr[2]
            hi1, lo1 = (p1 >> 32) & M32, p1 & M32
            ctr = [hi1 ^ ctr[1] ^ key[0], lo1, hi0 ^ ctr[3] ^ key[1], lo0]
            key[0] = (key[0] + self.W0) & M32
            key[1] = (key[1] + self.W1) & M32
        # 128-bit counter increment
        c = 0
        for i in range(4):
            c |= self.counter[i] << (32 * i)
        c = (c + 1) & ((1 << 128) - 1)
        self.counter = [(c >> (32 * i)) & M32 for i in range(4)]
        return ctr


class DitherGen:
    """prng/mod.rs DitherGen: buffered words + block-wise fill_dither."""

    def __init__(self):
        self.rng = Philox(RUN_SEED, WORKER, ROUND)
        self.buf, self.pos = [0, 0, 0, 0], 4

    def next_u32(self):
        if self.pos == 4:
            self.buf = self.rng.next_block()
            self.pos = 0
        v = self.buf[self.pos]
        self.pos += 1
        return v

    def next_f32(self):
        return np.float32(self.next_u32() >> 8) * np.float32(1.0 / 16777216.0)

    def fill_dither(self, half, n):
        half = np.float32(half)
        scale = np.float32(2.0) * half / np.float32(16777216.0)
        out = []
        # drain lanes buffered by a previous partial fill / scalar draw
        while self.pos < 4 and len(out) < n:
            out.append(np.float32(self.buf[self.pos] >> 8) * scale - half)
            self.pos += 1
        # whole Philox blocks (the Rust chunks_exact_mut(4) hot loop)
        while n - len(out) >= 4:
            b = self.rng.next_block()
            for j in range(4):
                out.append(np.float32(b[j] >> 8) * scale - half)
        # trailing partial block: buffer it so the next draw resumes mid-block
        if len(out) < n:
            self.buf = self.rng.next_block()
            self.pos = 0
            while len(out) < n:
                out.append(np.float32(self.buf[self.pos] >> 8) * scale - half)
                self.pos += 1
        return out


# --- coding/bitio.rs + pack.rs ---------------------------------------------

class BitWriter:
    def __init__(self):
        self.bytes = bytearray()
        self.bit_len = 0

    def push_bits(self, v, n):
        left = n
        while left > 0:
            slot = self.bit_len % 8
            if slot == 0:
                self.bytes.append(0)
            take = min(8 - slot, left)
            mask = (1 << take) - 1
            self.bytes[-1] |= ((v & mask) << slot) & 0xFF
            v >>= take
            left -= take
            self.bit_len += take

    def push_bit(self, b):
        self.push_bits(1 if b else 0, 1)

    def push_f32(self, x):
        self.push_bits(struct.unpack("<I", np.float32(x).tobytes())[0], 32)


def group_params(k):
    digits, value = 0, 1
    while value * k <= (1 << 64):
        value *= k
        digits += 1
    return digits, (value - 1).bit_length()


def pack_base_k_signed(indices, m, k, w):
    digits, bits = group_params(k)
    for lo in range(0, len(indices), digits):
        chunk = indices[lo:lo + digits]
        v = 0
        for q in reversed(chunk):
            assert -m <= q <= m
            v = v * k + (q + m)
        w.push_bits(v, bits)


# --- coding/huffman.rs (canonical Huffman, exact tie-break replica) ---------

MAX_CODE_LEN = 24


def huffman_code_lengths(freqs):
    n = len(freqs)
    live = [s for s in range(n) if freqs[s] > 0]
    lens = [0] * n
    if len(live) == 0:
        return lens
    if len(live) == 1:
        lens[live[0]] = 1
        return lens
    # heap-free Huffman mirroring the Rust merge loop: stable sort
    # descending by weight, pop the two smallest (list tail), push merged
    nodes = [[freqs[s], [s]] for s in live]
    while len(nodes) > 1:
        nodes.sort(key=lambda nd: -nd[0])  # stable, like sort_by_key(Reverse)
        a = nodes.pop()
        b = nodes.pop()
        for s in a[1] + b[1]:
            lens[s] += 1
        nodes.append([a[0] + b[0], a[1] + b[1]])
    if any(l > MAX_CODE_LEN for l in lens):
        bits = max(1, math.ceil(math.log2(len(live))))
        for s in live:
            lens[s] = bits
    return lens


def huffman_canonical_codes(lens):
    order = sorted((s for s in range(len(lens)) if lens[s] > 0),
                   key=lambda s: (lens[s], s))
    codes = [(0, 0)] * len(lens)
    code, prev_len = 0, 0
    for s in order:
        code <<= lens[s] - prev_len
        codes[s] = (code, lens[s])
        prev_len = lens[s]
        code += 1
    return codes


def huffman_encode_signed(q, m, w):
    symbols = [x + m for x in q]
    alphabet = 2 * m + 1
    freqs = [0] * alphabet
    for s in symbols:
        freqs[s] += 1
    lens = huffman_code_lengths(freqs)
    codes = huffman_canonical_codes(lens)
    for l in lens:
        w.push_bits(l, 5)
    for s in symbols:
        code, ln = codes[s]
        for i in range(ln - 1, -1, -1):  # MSB-first
            w.push_bit((code >> i) & 1 == 1)


# --- coding/arithmetic.rs (order-0 adaptive arithmetic coder) ---------------

AAC_CODE_BITS = 32
AAC_TOP = 1 << AAC_CODE_BITS
AAC_HALF = AAC_TOP // 2
AAC_QUARTER = AAC_TOP // 4
AAC_THREE_Q = 3 * AAC_QUARTER
AAC_MAX_TOTAL = 1 << 16
AAC_INCREMENT = 32


class AacModel:
    def __init__(self, alphabet):
        self.freq = [1] * alphabet
        self.total = alphabet

    def range(self, s):
        lo = sum(self.freq[:s])
        return lo, lo + self.freq[s], self.total

    def update(self, s):
        self.freq[s] += AAC_INCREMENT
        self.total += AAC_INCREMENT
        if self.total > AAC_MAX_TOTAL:
            self.total = 0
            for i, f in enumerate(self.freq):
                self.freq[i] = max(f >> 1, 1)
                self.total += self.freq[i]


def aac_encode_signed(q, m, w):
    symbols = [x + m for x in q]
    alphabet = 2 * m + 1
    model = AacModel(alphabet)
    low, high, pending = 0, AAC_TOP - 1, 0

    def emit(bit):
        nonlocal pending
        w.push_bit(bit)
        while pending > 0:
            w.push_bit(not bit)
            pending -= 1

    for s in symbols:
        c_lo, c_hi, total = model.range(s)
        span = high - low + 1
        high = low + span * c_hi // total - 1
        low = low + span * c_lo // total
        while True:
            if high < AAC_HALF:
                emit(False)
            elif low >= AAC_HALF:
                emit(True)
                low -= AAC_HALF
                high -= AAC_HALF
            elif low >= AAC_QUARTER and high < AAC_THREE_Q:
                pending += 1
                low -= AAC_QUARTER
                high -= AAC_QUARTER
            else:
                break
            low <<= 1
            high = (high << 1) | 1
        model.update(s)
    pending += 1
    if low < AAC_QUARTER:
        emit(False)
    else:
        emit(True)


CODEC_RAW, CODEC_HUFFMAN, CODEC_AAC = 0, 1, 2


def write_indices_coded(w, codec, indices, m):
    if codec == CODEC_RAW:
        pack_base_k_signed(indices, m, 2 * m + 1, w)
    elif codec == CODEC_HUFFMAN:
        huffman_encode_signed(indices, m, w)
    else:
        aac_encode_signed(indices, m, w)


# --- f32 helpers ------------------------------------------------------------

def rha(x):
    """f32::round — round half away from zero, on the exact f32 value."""
    x = float(x)
    return math.floor(x + 0.5) if x >= 0.0 else math.ceil(x - 0.5)


def linf(g):
    m = np.float32(0.0)
    for v in g:
        a = np.float32(abs(np.float32(v)))
        if a > m:
            m = a
    return m if m > 0 else np.float32(1.0)


def uq(t, delta):
    return np.float32(delta) * np.float32(rha(np.float32(t) / np.float32(delta)))


# --- quantizer encodes (mirroring src/quant/*.rs) ---------------------------

def enc_baseline(g):
    w = BitWriter()
    for v in g:
        w.push_f32(v)
    return w, 0, 0


def dq_indices(g, delta, m, dither):
    kappa = linf(g)
    inv_kappa = np.float32(1.0) / kappa
    inv_delta = np.float32(1.0) / np.float32(delta)
    u = dither.fill_dither(np.float32(delta) / np.float32(2.0), len(g))
    idx = []
    for gi, ui in zip(g, u):
        t = (np.float32(gi) * inv_kappa + ui) * inv_delta
        idx.append(max(-m, min(m, rha(t))))
    return kappa, idx


def enc_dithered(g, delta, m, codec=CODEC_RAW):
    d = DitherGen()
    kappa, idx = dq_indices(g, delta, m, d)
    w = BitWriter()
    w.push_f32(kappa)
    write_indices_coded(w, codec, idx, m)
    return w, m, 1


def enc_partitioned(g, delta, m, k_parts):
    d = DitherGen()
    n = len(g)
    base, rem = n // k_parts, n % k_parts
    scales, idx = [], []
    off = 0
    for i in range(k_parts):
        ln = base + (1 if i < rem else 0)
        kappa, part_idx = dq_indices(g[off:off + ln], delta, m, d)
        scales.append(kappa)
        idx.extend(part_idx)
        off += ln
    w = BitWriter()
    for s in scales:
        w.push_f32(s)
    pack_base_k_signed(idx, m, 2 * m + 1, w)
    return w, m, k_parts


def enc_terngrad(g):
    d = DitherGen()
    # tensor::mean_var in f64, left-to-right
    mean = 0.0
    for v in g:
        mean += float(np.float32(v))
    mean /= len(g)
    var = 0.0
    for v in g:
        var += (float(np.float32(v)) - mean) ** 2
    var /= len(g)
    c = np.float32(2.5 * math.sqrt(var))

    def clip(x):
        x = np.float32(x)
        if c > 0:
            return np.float32(max(np.float32(-c), min(c, x)))
        return x

    s = np.float32(0.0)
    for x in g:
        a = np.float32(abs(clip(x)))
        if a > s:
            s = a
    if s == 0:
        s = np.float32(1.0)
    idx = []
    for x in g:
        xc = clip(x)
        p = np.float32(abs(xc)) / s
        if float(d.next_f32()) < float(p):
            idx.append(1 if xc >= 0 else -1)
        else:
            idx.append(0)
    w = BitWriter()
    w.push_f32(s)
    pack_base_k_signed(idx, 1, 3, w)
    return w, 1, 1


def enc_onebit(g):
    # first round: residual = 0, so v = g; means in f64
    sum_pos = n_pos = sum_neg = n_neg = 0
    for v in g:
        if np.float32(v) >= 0:
            sum_pos += float(np.float32(v))
            n_pos += 1
        else:
            sum_neg += float(np.float32(v))
            n_neg += 1
    mean_pos = np.float32(sum_pos / n_pos) if n_pos else np.float32(0.0)
    mean_neg = np.float32(sum_neg / n_neg) if n_neg else np.float32(0.0)
    w = BitWriter()
    w.push_f32(mean_pos)
    w.push_f32(mean_neg)
    for v in g:
        w.push_bit(np.float32(v) >= 0)
    return w, 0, 2


def enc_nested(g, d1, ratio, alpha, codec=CODEC_RAW):
    d = DitherGen()
    m = (ratio - 1) // 2
    kappa = linf(g)
    inv_kappa = np.float32(1.0) / kappa
    d1f = np.float32(d1)
    d2f = d1f * np.float32(ratio)
    u = d.fill_dither(d1f / np.float32(2.0), len(g))
    inv_d1 = np.float32(1.0) / d1f
    idx = []
    for gi, ui in zip(g, u):
        t = np.float32(alpha) * (np.float32(gi) * inv_kappa) + ui
        s = uq(t, d1f) - uq(t, d2f)
        idx.append(max(-m, min(m, rha(np.float32(s) * inv_d1))))
    w = BitWriter()
    w.push_f32(kappa)
    write_indices_coded(w, codec, idx, m)
    return w, m, 1


def enc_nuqsgd(g, m, codec=CODEC_RAW):
    d = DitherGen()
    # tensor::l2_norm: f64 left-to-right sum of squares, sqrt, cast to f32
    acc = 0.0
    for v in g:
        fv = float(np.float32(v))
        acc += fv * fv
    kappa = np.float32(math.sqrt(acc))
    inv_kappa = np.float32(1.0) / kappa if kappa > 0 else np.float32(0.0)
    # levels[0] = 0, levels[j] = 2^(j - m): exact binary powers in f32
    levels = [np.float32(0.0)] + [np.float32(2.0 ** (j - m)) for j in range(1, m + 1)]
    u = d.fill_dither(np.float32(0.5), len(g))
    idx = []
    for gi, ui in zip(g, u):
        u01 = np.float32(ui) + np.float32(0.5)
        r = np.float32(abs(np.float32(gi))) * inv_kappa
        j = 0
        while j + 1 <= m and r >= levels[j + 1]:
            j += 1
        if j >= m:
            q = m
        else:
            p = (r - levels[j]) / (levels[j + 1] - levels[j])
            q = j + 1 if u01 < p else j
        idx.append(-q if np.float32(gi) < 0 else q)
    w = BitWriter()
    w.push_f32(kappa)
    write_indices_coded(w, codec, idx, m)
    return w, m, 1


# --- wire-v2 framing (src/quant/mod.rs) -------------------------------------

def frame_message(scheme_id, frames, codec=CODEC_RAW):
    """frames: list of (n, m, n_scales, BitWriter)."""
    out = bytearray(b"NQ")
    out.append(3)              # version
    out.append(scheme_id)
    out.append(codec)          # payload codec byte (wire v3)
    out += struct.pack("<I", len(frames))
    for n, m, n_scales, w in frames:
        out += struct.pack("<Q", n)
        out += struct.pack("<i", m)
        out += struct.pack("<I", n_scales)
        out += struct.pack("<Q", w.bit_len)
        out += bytes(w.bytes)
        assert len(w.bytes) == (w.bit_len + 7) // 8
    out += struct.pack("<I", binascii.crc32(bytes(out)) & 0xFFFFFFFF)
    return bytes(out)


def emit(name, scheme_id, enc, codec=CODEC_RAW):
    w, m, n_scales = enc
    msg = frame_message(scheme_id, [(len(G), m, n_scales, w)], codec)
    path = OUT_DIR / f"{name}.hex"
    path.write_text(msg.hex() + "\n")
    print(f"{name:14s} {len(msg):4d} bytes  {msg.hex()}")


def main():
    emit("baseline", 0, enc_baseline(G))
    emit("dqsg", 1, enc_dithered(G, 1.0, 1))
    emit("dqsg_part", 2, enc_partitioned(G, 0.5, 2, 2))
    emit("qsgd", 3, enc_dithered(G, 1.0, 1))      # Lemma 2: same payload shape
    emit("terngrad", 4, enc_terngrad(G))
    emit("onebit", 5, enc_onebit(G))
    emit("nested", 6, enc_nested(G, 0.25, 3, 1.0))
    emit("nuqsgd", 7, enc_nuqsgd(G, 2))
    # codec-byte variants: same gradient/dither, entropy-coded index lanes
    emit("dqsg_huffman", 1, enc_dithered(G, 1.0, 1, CODEC_HUFFMAN), CODEC_HUFFMAN)
    emit("dqsg_aac", 1, enc_dithered(G, 1.0, 1, CODEC_AAC), CODEC_AAC)
    emit("nested_aac", 6, enc_nested(G, 0.25, 3, 1.0, CODEC_AAC), CODEC_AAC)
    emit("nuqsgd_huffman", 7, enc_nuqsgd(G, 2, CODEC_HUFFMAN), CODEC_HUFFMAN)


if __name__ == "__main__":
    main()
