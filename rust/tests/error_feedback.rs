//! The ISSUE-9 acceptance pin: an EF-enabled NUQSGD cluster run ships
//! strictly fewer transmitted bits than the fixed-k DQSG baseline at a
//! matched message count, and still reaches a final loss no worse — the
//! whole point of carrying a residual lane into an aggressive nonuniform
//! operating point.
//!
//! Scenario design (why these constants):
//! * `noise: 0.0` — the synthetic quadratic is run without injected
//!   gradient noise so the *only* stochasticity is quantization error.
//!   With the default absolute noise both runs sit on the same injected
//!   floor and the comparison degenerates to a seed-level coin flip.
//! * baseline `Dithered { delta: 1/4 }` + `Raw`: a 9-level alphabet,
//!   log2(9) ~ 3.17 group-packed bits per coordinate. Its unbiased
//!   per-coordinate error (delta * linf / sqrt(12), Thm. 1) compounds
//!   multiplicatively over the run.
//! * EF run `Nuqsgd { m: 7 }` + `Huffman`: a 15-level logarithmic
//!   alphabet whose index distribution on a dense gradient concentrates
//!   on the few levels around |v_i|/||v|| ~ 1/sqrt(n), so the entropy
//!   coder lands near ~2.7 bits per coordinate — under the baseline's
//!   3.17 with margin. Without EF this coarse nonuniform scheme is far
//!   *noisier* than the baseline; the residual lane is what cashes the
//!   cheap wire rate back into trajectory quality.
//! * `lr: 0.5`, 50 rounds, 2 workers: a contraction of 0.5^50 keeps the
//!   final f32 eval loss (~1e-31) far from both underflow and the
//!   round-off regime, while the baseline's variance inflation
//!   (~(1 + lr^2 c^2 / W)^rounds ~ 5x) dwarfs the EF run's residual
//!   offset (~1.1x).

use ndq::quant::{PayloadCodec, Scheme};
use ndq::testing::cluster::{run_scenario, ClusterScenario};

fn quantization_noise_only(scheme: Scheme, codec: PayloadCodec, ef: bool) -> ClusterScenario {
    ClusterScenario {
        workers: 2,
        n_params: 2000,
        rounds: 50,
        seed: 271828,
        scheme,
        scheme_p2: None,
        codec,
        error_feedback: ef,
        lr: 0.5,
        noise: 0.0,
        eval_every: 10,
        ..ClusterScenario::default()
    }
}

fn dqsg_baseline() -> ClusterScenario {
    quantization_noise_only(Scheme::Dithered { delta: 0.25 }, PayloadCodec::Raw, false)
}

fn nuq_ef() -> ClusterScenario {
    quantization_noise_only(Scheme::Nuqsgd { m: 7 }, PayloadCodec::Huffman, true)
}

#[test]
fn ef_nuqsgd_undercuts_dqsg_bits_at_no_worse_loss() {
    let dqsg = run_scenario(dqsg_baseline()).unwrap();
    let nuq = run_scenario(nuq_ef()).unwrap();

    // matched message count: same clean link, same workers x rounds —
    // the bits saving is per-message, not from hearing fewer workers
    assert_eq!(nuq.comm.messages, dqsg.comm.messages);
    assert_eq!(nuq.delivery.len(), dqsg.delivery.len());

    // strictly fewer transmitted bits on the wire
    assert!(
        nuq.comm.total_transmitted_bits < dqsg.comm.total_transmitted_bits,
        "nuqsgd+huffman {} bits vs dqsg raw {} bits",
        nuq.comm.total_transmitted_bits,
        dqsg.comm.total_transmitted_bits
    );

    // ...and final loss no worse than the fixed-k uniform baseline
    assert!(
        nuq.final_eval_loss <= dqsg.final_eval_loss,
        "ef+nuqsgd loss {} vs dqsg loss {}",
        nuq.final_eval_loss,
        dqsg.final_eval_loss
    );

    // both trajectories actually contracted (and neither underflowed to
    // a vacuous 0.0 — the comparison above must be about real numbers)
    assert!(nuq.final_eval_loss > 0.0, "{}", nuq.final_eval_loss);
    assert!(dqsg.final_eval_loss > 0.0, "{}", dqsg.final_eval_loss);
    assert!(nuq.final_eval_loss < 1e-20, "{}", nuq.final_eval_loss);

    // the EF run is billed exactly, in a single per-spec ledger lane
    assert_eq!(nuq.comm.per_spec.len(), 1, "{:?}", nuq.comm.per_spec.keys());
    let (label, lane) = nuq.comm.per_spec.iter().next().unwrap();
    assert!(label.contains("NUQSGD"), "{label}");
    assert_eq!(lane.messages, nuq.comm.messages);
    assert_eq!(
        lane.transmitted_bits.to_bits(),
        nuq.comm.total_transmitted_bits.to_bits()
    );
    assert_eq!(lane.raw_bits.to_bits(), nuq.comm.total_raw_bits.to_bits());

    // the knob is visible in the run identity
    assert!(nuq.config_label.contains("ef=on"), "{}", nuq.config_label);
    assert!(!dqsg.config_label.contains("ef=on"), "{}", dqsg.config_label);
}

#[test]
fn ef_is_what_makes_the_coarse_nonuniform_point_trainable() {
    // same scheme, same codec, same seed — the residual lane is the only
    // difference, and without it the coarse log-grid's quantization noise
    // compounds into a trajectory orders of magnitude worse
    let with_ef = run_scenario(nuq_ef()).unwrap();
    let without = run_scenario(ClusterScenario { error_feedback: false, ..nuq_ef() }).unwrap();
    assert_eq!(with_ef.comm.messages, without.comm.messages);
    assert!(
        with_ef.final_eval_loss < without.final_eval_loss,
        "ef {} vs plain {}",
        with_ef.final_eval_loss,
        without.final_eval_loss
    );
}

#[test]
fn ef_nuqsgd_runs_are_bit_reproducible() {
    let a = run_scenario(nuq_ef()).unwrap();
    let b = run_scenario(nuq_ef()).unwrap();
    assert_eq!(a.fingerprint(), b.fingerprint());
    assert_eq!(a.comm.per_spec, b.comm.per_spec);
    assert_eq!(a.final_eval_loss.to_bits(), b.final_eval_loss.to_bits());
    // a different seed moves the digest
    let c = run_scenario(ClusterScenario { seed: 314159, ..nuq_ef() }).unwrap();
    assert_ne!(a.fingerprint(), c.fingerprint());
}
