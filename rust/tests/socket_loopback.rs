//! Socket-transport acceptance suite: the `ndq serve` / `ndq worker`
//! stack must be a *transparent* replacement for the in-process cluster
//! harness.
//!
//! Pins the PR-6 tentpole claims:
//! * parity — a loopback multi-worker run over real sockets (UDS and
//!   TCP) produces a `TrainReport::fingerprint()` **bit-identical** to
//!   [`run_scenario`] on the same scenario, including under injected
//!   faults, quorum policies, NDQSG mixes, entropy codecs, and per-round
//!   re-leveling;
//! * robustness — the leader survives peers that die mid-run, billing
//!   them as first-class disconnects instead of hanging or crashing;
//! * process isolation — the same parity holds for the real binaries
//!   (`ndq serve` + N `ndq worker` processes vs `ndq cluster`).

use std::path::PathBuf;
use std::time::Duration;

use ndq::comm::net::{NetAddr, NetListener};
use ndq::comm::{DownlinkPolicy, FaultPlan, RoundPolicy};
use ndq::quant::{PayloadCodec, Scheme};
use ndq::testing::cluster::{
    run_scenario, serve_listener, serve_scenario, worker_connect, ClusterScenario, ServeOptions,
};
use ndq::train::LevelPolicy;

/// A collision-free socket path in the test tempdir.
fn uds_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ndq-{}-{tag}.sock", std::process::id()))
}

fn opts() -> ServeOptions {
    ServeOptions {
        io_timeout: Duration::from_secs(30),
    }
}

/// Serve `sc` on `addr` with one in-process thread per worker dialing it,
/// and return the leader's report.
fn serve_with_thread_workers(
    sc: ClusterScenario,
    addr: NetAddr,
) -> ndq::Result<ndq::train::TrainReport> {
    let listener = NetListener::bind(&addr)?;
    let dial = listener.local_addr()?;
    let peers: Vec<_> = (0..sc.workers)
        .map(|_| {
            let dial = dial.clone();
            std::thread::spawn(move || worker_connect(&dial, Duration::from_secs(10)))
        })
        .collect();
    let report = serve_listener(sc, listener, opts())?;
    for p in peers {
        p.join().expect("worker thread panicked")?;
    }
    Ok(report)
}

fn faulty_scenario() -> ClusterScenario {
    // every moving part at once: NDQSG mix, huffman codec, a level
    // schedule, a fault plan with all five fault kinds, and a quorum
    // policy that tolerates the losses
    ClusterScenario {
        workers: 6,
        n_params: 1500,
        rounds: 25,
        seed: 20260808,
        scheme: Scheme::Dithered { delta: 1.0 / 3.0 },
        scheme_p2: Some(Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 }),
        codec: PayloadCodec::Huffman,
        levels_policy: LevelPolicy::parse("schedule:0=15,10=7,20=3").unwrap(),
        plan: FaultPlan::new()
            .drop_at(1, 3)
            .corrupt_at(2, 5)
            .duplicate_at(3, 7)
            .delay_at(4, 9, 2)
            .disconnect_at(5, 12)
            .straggle(0, 1.5),
        policy: RoundPolicy::Quorum(4),
        eval_every: 5,
        ..ClusterScenario::default()
    }
}

#[test]
fn uds_loopback_matches_in_process_fingerprint() {
    let sc = ClusterScenario::default();
    let want = run_scenario(sc.clone()).unwrap();
    let addr = NetAddr::Uds(uds_path("clean"));
    let got = serve_with_thread_workers(sc, addr).unwrap();
    assert_eq!(
        got.fingerprint(),
        want.fingerprint(),
        "socket transport moved the clean-run fingerprint"
    );
    assert_eq!(got.comm.messages, want.comm.messages);
    assert_eq!(got.rounds_failed, 0);
    assert_eq!(
        got.final_eval_loss.to_bits(),
        want.final_eval_loss.to_bits()
    );
}

#[test]
fn uds_loopback_matches_under_faults_quorum_and_releveling() {
    let sc = faulty_scenario();
    let want = run_scenario(sc.clone()).unwrap();
    // the scenario genuinely exercised the fault machinery
    assert!(want.comm.faulted_msgs() > 0);
    assert!(want.comm.per_spec.len() > 1);
    let addr = NetAddr::Uds(uds_path("faulty"));
    let got = serve_with_thread_workers(sc, addr).unwrap();
    assert_eq!(
        got.fingerprint(),
        want.fingerprint(),
        "socket transport moved the faulty-run fingerprint"
    );
    assert_eq!(got.delivery, want.delivery);
    assert_eq!(got.comm.per_spec, want.comm.per_spec);
    assert_eq!(
        got.comm.total_transmitted_bits.to_bits(),
        want.comm.total_transmitted_bits.to_bits()
    );
}

#[test]
fn quantized_downlink_keeps_socket_parity_and_saves_bits() {
    // the downlink lane over real sockets: workers reconstruct params
    // from coded deltas, and the result is bit-identical to the
    // in-process harness running the same policy — while the ledger
    // shows strictly fewer broadcast bits than the full-precision twin
    let sc = ClusterScenario {
        workers: 4,
        rounds: 15,
        n_params: 900,
        eval_every: 5,
        downlink: DownlinkPolicy::DeltaQuantized(Scheme::Dithered { delta: 1.0 / 3.0 }),
        ..ClusterScenario::default()
    };
    let full = ClusterScenario {
        downlink: DownlinkPolicy::Full,
        ..sc.clone()
    };
    let want = run_scenario(sc.clone()).unwrap();
    let addr = NetAddr::Uds(uds_path("downlink"));
    let got = serve_with_thread_workers(sc, addr).unwrap();
    assert_eq!(
        got.fingerprint(),
        want.fingerprint(),
        "socket transport moved the quantized-downlink fingerprint"
    );
    assert_eq!(
        got.final_eval_loss.to_bits(),
        want.final_eval_loss.to_bits()
    );
    let full_report = run_scenario(full).unwrap();
    assert_eq!(got.comm.bcast_msgs, full_report.comm.bcast_msgs);
    assert!(
        got.comm.total_bcast_bits < full_report.comm.total_bcast_bits,
        "quantized downlink must ship fewer bits: {} vs {}",
        got.comm.total_bcast_bits,
        full_report.comm.total_bcast_bits
    );
}

#[test]
fn tcp_ephemeral_port_loopback_matches_too() {
    let sc = ClusterScenario {
        workers: 3,
        rounds: 12,
        n_params: 800,
        eval_every: 4,
        ..ClusterScenario::default()
    };
    let want = run_scenario(sc.clone()).unwrap();
    let got =
        serve_with_thread_workers(sc, NetAddr::parse("tcp:127.0.0.1:0").unwrap()).unwrap();
    assert_eq!(got.fingerprint(), want.fingerprint());
}

#[test]
fn leader_survives_a_peer_that_dies_mid_run() {
    let sc = ClusterScenario {
        workers: 3,
        rounds: 10,
        n_params: 500,
        policy: RoundPolicy::Quorum(2),
        eval_every: 5,
        ..ClusterScenario::default()
    };
    let addr = NetAddr::Uds(uds_path("dying"));
    let listener = NetListener::bind(&addr).unwrap();
    let dial = listener.local_addr().unwrap();
    // two faithful peers...
    let peers: Vec<_> = (0..2)
        .map(|_| {
            let dial = dial.clone();
            std::thread::spawn(move || worker_connect(&dial, Duration::from_secs(10)))
        })
        .collect();
    // ...and one that handshakes, then hangs up without a Bye
    let saboteur = {
        let dial = dial.clone();
        std::thread::spawn(move || {
            use ndq::comm::net::{FrameReader, NetMsg, NetStream, NET_VERSION};
            let mut s = NetStream::connect_retry(&dial, Duration::from_secs(10)).unwrap();
            NetMsg::Hello { version: NET_VERSION }.write_to(&mut s).unwrap();
            let mut r = FrameReader::new();
            assert!(matches!(
                r.read_msg(&mut s).unwrap(),
                NetMsg::Start { .. }
            ));
            s.shutdown(); // vanish before the first round
        })
    };
    let report = serve_listener(
        sc,
        listener,
        ServeOptions {
            io_timeout: Duration::from_secs(5),
        },
    )
    .unwrap();
    saboteur.join().unwrap();
    for p in peers {
        p.join().expect("worker thread panicked").unwrap();
    }
    // the dead peer is a first-class disconnect: quorum keeps stepping,
    // every surviving round hears the other two workers
    assert_eq!(report.comm.disconnects, 1);
    assert_eq!(report.rounds_failed, 0);
    assert!(report
        .delivery
        .iter()
        .skip(1)
        .all(|d| d.received == 2), "{:?}", report.delivery);
    assert!(report.final_eval_loss.is_finite());
}

#[test]
fn serve_scenario_binds_for_itself_as_documented() {
    // the plain entry point (what `ndq serve` calls) — bind happens
    // inside, so workers must retry-connect; cover it once on UDS
    let sc = ClusterScenario {
        workers: 2,
        rounds: 6,
        n_params: 300,
        eval_every: 3,
        ..ClusterScenario::default()
    };
    let want = run_scenario(sc.clone()).unwrap();
    let addr = NetAddr::Uds(uds_path("selfbind"));
    let peers: Vec<_> = (0..sc.workers)
        .map(|_| {
            let dial = addr.clone();
            std::thread::spawn(move || worker_connect(&dial, Duration::from_secs(10)))
        })
        .collect();
    let got = serve_scenario(sc, &addr, opts()).unwrap();
    for p in peers {
        p.join().expect("worker thread panicked").unwrap();
    }
    assert_eq!(got.fingerprint(), want.fingerprint());
}

/// Extract the `fingerprint: <hex>` line a cluster/serve run prints.
fn fingerprint_of(out: &std::process::Output) -> String {
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "binary failed\nstdout:\n{stdout}\nstderr:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    stdout
        .lines()
        .find_map(|l| l.trim().strip_prefix("fingerprint: "))
        .unwrap_or_else(|| panic!("no fingerprint line in:\n{stdout}"))
        .to_string()
}

#[test]
fn multi_process_serve_matches_cluster_binary() {
    let bin = env!("CARGO_BIN_EXE_ndq");
    let sock = uds_path("procs");
    let scenario_flags = [
        "--workers", "3",
        "--n", "600",
        "--rounds", "8",
        "--seed", "77",
        "--scheme", "dqsg:0.333333",
        "--scheme-p2", "nested:0.333333:3:1.0",
        "--codec", "huffman",
        "--round-policy", "quorum:2",
    ];

    let mut serve = std::process::Command::new(bin)
        .arg("serve")
        .args(scenario_flags)
        .arg("--bind")
        .arg(format!("uds:{}", sock.display()))
        .arg("--io-timeout")
        .arg("30")
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn ndq serve");
    let workers: Vec<_> = (0..3)
        .map(|_| {
            std::process::Command::new(bin)
                .arg("worker")
                .arg("--connect")
                .arg(format!("uds:{}", sock.display()))
                .arg("--timeout")
                .arg("30")
                .stdout(std::process::Stdio::piped())
                .stderr(std::process::Stdio::piped())
                .spawn()
                .expect("spawn ndq worker")
        })
        .collect();

    let serve_out = serve.wait_with_output().expect("wait on ndq serve");
    for w in workers {
        let out = w.wait_with_output().expect("wait on ndq worker");
        assert!(
            out.status.success(),
            "worker failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
    }

    let cluster_out = std::process::Command::new(bin)
        .arg("cluster")
        .args(scenario_flags)
        .output()
        .expect("run ndq cluster");

    assert_eq!(
        fingerprint_of(&serve_out),
        fingerprint_of(&cluster_out),
        "serve stdout:\n{}",
        String::from_utf8_lossy(&serve_out.stdout)
    );
}
