//! The `comm::Session` / `RoundAggregator` contract, pinned against
//! history: for every arrival permutation of a round's message set, the
//! streaming aggregator must produce a **bit-identical** average to the
//! original batch `Server::decode_round` (wire-protocol v2 era), whose
//! exact math is kept below as `RefServer` — a verbatim reference
//! implementation, deliberately duplicated here so refactors of the
//! production path cannot silently move the goalposts.

use ndq::comm::{RoundSpec, Session, WorkerMsg};
use ndq::prng::{DitherStream, Xoshiro256};
use ndq::quant::{
    frame_slices, EfState, GradQuantizer, PayloadCodec, Scheme, SchemeId, SchemeRegistry,
};

// ---------------------------------------------------------------------------
// Reference implementation: the pre-session batch decoder.
// ---------------------------------------------------------------------------

struct RefServer {
    registry: SchemeRegistry,
    worker_ids: Vec<SchemeId>,
    streams: Vec<DitherStream>,
    in_p1: Vec<bool>,
    n_params: usize,
}

impl RefServer {
    fn new(schemes: &[Scheme], run_seed: u64, n_params: usize) -> RefServer {
        RefServer {
            registry: SchemeRegistry::from_schemes(schemes).unwrap(),
            worker_ids: schemes.iter().map(|s| s.id()).collect(),
            streams: (0..schemes.len())
                .map(|p| DitherStream::new(run_seed, p as u32))
                .collect(),
            in_p1: schemes.iter().map(|s| !s.needs_side_info()).collect(),
            n_params,
        }
    }

    /// Verbatim port of the original `Server::decode_round`: sort by worker
    /// id, P1 pass building the running average, then P2 pass decoding each
    /// message against (and folding it into) that running average.
    fn decode_round(&self, msgs: &[WorkerMsg]) -> ndq::Result<Vec<f32>> {
        anyhow::ensure!(!msgs.is_empty(), "no worker messages");
        for msg in msgs {
            anyhow::ensure!(msg.worker < self.worker_ids.len(), "unknown worker");
            anyhow::ensure!(msg.wire.scheme == self.worker_ids[msg.worker], "spoof");
            anyhow::ensure!(msg.wire.n() == self.n_params, "bad n");
        }
        let mut order: Vec<usize> = (0..msgs.len()).collect();
        order.sort_by_key(|&i| msgs[i].worker);
        for w in order.windows(2) {
            anyhow::ensure!(
                msgs[w[0]].worker != msgs[w[1]].worker,
                "duplicate worker"
            );
        }

        let mut avg = vec![0f32; self.n_params];
        let mut count = 0usize;
        for &i in &order {
            let msg = &msgs[i];
            if self.in_p1[msg.worker] {
                let g = self.decode_one(msg, None)?;
                accumulate(&mut avg, &g, &mut count);
            }
        }
        anyhow::ensure!(
            count > 0 || msgs.iter().all(|m| self.in_p1[m.worker]),
            "NDQSG requires at least one P1 worker"
        );
        for &i in &order {
            let msg = &msgs[i];
            if !self.in_p1[msg.worker] {
                let g = self.decode_one(msg, Some(&avg))?;
                accumulate(&mut avg, &g, &mut count);
            }
        }
        Ok(avg)
    }

    fn decode_one(&self, msg: &WorkerMsg, side: Option<&[f32]>) -> ndq::Result<Vec<f32>> {
        let mut gen = self.streams[msg.worker].round(msg.round);
        self.registry.decode(&msg.wire, &mut gen, side)
    }
}

fn accumulate(avg: &mut [f32], g: &[f32], count: &mut usize) {
    *count += 1;
    let inv = 1.0 / *count as f32;
    for (a, &gi) in avg.iter_mut().zip(g) {
        *a += (gi - *a) * inv;
    }
}

// ---------------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------------

fn correlated_grads(n: usize, p: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Xoshiro256::new(seed);
    let base: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.2).collect();
    (0..p)
        .map(|_| {
            base.iter()
                .map(|&b| b + rng.next_normal() * 0.01)
                .collect()
        })
        .collect()
}

/// Encode each worker's gradient as a `tensor_frames`-frame wire message.
fn make_msgs(
    schemes: &[Scheme],
    gs: &[Vec<f32>],
    run_seed: u64,
    round: u64,
    tensor_frames: usize,
) -> Vec<WorkerMsg> {
    gs.iter()
        .enumerate()
        .map(|(p, g)| {
            let mut q = schemes[p].build();
            let stream = DitherStream::new(run_seed, p as u32);
            let slices = frame_slices(g, tensor_frames);
            let wire = q.encode_tensors(&slices, &mut stream.round(round));
            WorkerMsg::new(p, round, 0.0, wire)
        })
        .collect()
}

fn shuffled(len: usize, rng: &mut Xoshiro256) -> Vec<usize> {
    let mut order: Vec<usize> = (0..len).collect();
    for i in (1..len).rev() {
        let j = rng.next_below(i as u32 + 1) as usize;
        order.swap(i, j);
    }
    order
}

/// Stream the messages into `session` in the given arrival order and
/// assert the finished average is bit-identical to `reference`.
fn assert_permutation_matches(
    session: &mut Session,
    msgs: &[WorkerMsg],
    order: &[usize],
    reference: &[f32],
) {
    let mut agg = session.begin_round();
    for &i in order {
        agg.push(msgs[i].clone()).unwrap();
    }
    let got = agg.finish().unwrap();
    assert_eq!(
        got, reference,
        "aggregate depends on arrival order {order:?}"
    );
    session.recycle(got);
}

// ---------------------------------------------------------------------------
// The property tests
// ---------------------------------------------------------------------------

#[test]
fn prop_permutation_bit_identity_every_scheme_mix() {
    // one worker per wire scheme id — the full codec zoo in one round,
    // NDQSG included (worker 6 is the sole P2 member) — multi-frame
    // messages, 24 random arrival permutations per round
    let schemes = vec![
        Scheme::Baseline,
        Scheme::Dithered { delta: 1.0 / 3.0 },
        Scheme::DitheredPartitioned { delta: 0.5, k: 4 },
        Scheme::Qsgd { m: 1 },
        Scheme::Terngrad,
        Scheme::OneBit,
        Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
    ];
    let n = 1500;
    let gs = correlated_grads(n, schemes.len(), 42);
    let mut rng = Xoshiro256::new(0xA11);
    let mut session = Session::new(&schemes, 7, n).unwrap();
    for (round, frames) in [(0u64, 1usize), (1, 3), (2, 5)] {
        let msgs = make_msgs(&schemes, &gs, 7, round, frames);
        let reference = RefServer::new(&schemes, 7, n).decode_round(&msgs).unwrap();
        // batch path through the same session
        assert_eq!(session.decode_round(&msgs).unwrap(), reference);
        // streaming path over random arrival orders, one shared session
        // (proves scratch reuse across rounds cannot leak state)
        for _ in 0..24 {
            let order = shuffled(msgs.len(), &mut rng);
            assert_permutation_matches(&mut session, &msgs, &order, &reference);
        }
    }
}

#[test]
fn prop_permutation_bit_identity_ndqsg_group_split() {
    // the Fig.-6 deployment: P1 = 2x DQSG, P2 = 3x NDQSG — side information
    // is built from P1 and refined sequentially through P2, so this is the
    // mix where arrival order would matter if canonicalization were broken
    let schemes = vec![
        Scheme::Dithered { delta: 1.0 / 3.0 },
        Scheme::Dithered { delta: 1.0 / 3.0 },
        Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
        Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
        Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
    ];
    let n = 2000;
    let mut rng = Xoshiro256::new(0xB22);
    let mut session = Session::new(&schemes, 21, n).unwrap();
    for round in 0..4u64 {
        let gs = correlated_grads(n, schemes.len(), 500 + round);
        let msgs = make_msgs(&schemes, &gs, 21, round, 2);
        let reference = RefServer::new(&schemes, 21, n)
            .decode_round(&msgs)
            .unwrap();
        for _ in 0..30 {
            let order = shuffled(msgs.len(), &mut rng);
            assert_permutation_matches(&mut session, &msgs, &order, &reference);
        }
        // the P2-first worst case explicitly (all queued until bootstrap)
        assert_permutation_matches(&mut session, &msgs, &[4, 3, 2, 1, 0], &reference);
    }
}

#[test]
fn prop_partial_round_matches_reference_subset_semantics() {
    // rounds where some workers never report: the aggregator must fold the
    // present subset exactly like the reference decodes that subset
    let schemes = vec![
        Scheme::Dithered { delta: 1.0 / 3.0 },
        Scheme::Dithered { delta: 1.0 / 3.0 },
        Scheme::Dithered { delta: 1.0 / 3.0 },
        Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
        Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
    ];
    let n = 900;
    let gs = correlated_grads(n, schemes.len(), 9);
    let msgs = make_msgs(&schemes, &gs, 5, 0, 1);
    let reference_server = RefServer::new(&schemes, 5, n);
    let mut session = Session::new(&schemes, 5, n).unwrap();
    let mut rng = Xoshiro256::new(0xC33);
    // drop each worker in turn, and a couple of two-worker drops
    let subsets: Vec<Vec<usize>> = vec![
        vec![1, 2, 3, 4],
        vec![0, 2, 3, 4],
        vec![0, 1, 3, 4],
        vec![0, 1, 2, 4],
        vec![0, 1, 2, 3],
        vec![0, 3, 4],
        vec![1, 2],
    ];
    for subset in subsets {
        let sub_msgs: Vec<WorkerMsg> = subset.iter().map(|&i| msgs[i].clone()).collect();
        let reference = reference_server.decode_round(&sub_msgs).unwrap();
        for _ in 0..10 {
            let order = shuffled(sub_msgs.len(), &mut rng);
            assert_permutation_matches(&mut session, &sub_msgs, &order, &reference);
        }
    }
}

#[test]
fn prop_mixed_spec_rounds_fold_bit_identically_and_ledger_stays_exact() {
    // The round-plan engine's session contract: a run whose rounds ship
    // under DIFFERENT RoundSpecs (re-leveled alphabets, different codecs)
    // must still fold every round bit-identically to the verbatim
    // reference under any arrival permutation, and the per-spec ledger
    // lanes must equal the sum of the encode-time BitMetrics of exactly
    // the messages billed to each spec.
    let base = RoundSpec {
        scheme: Scheme::Dithered { delta: 1.0 / 3.0 },
        scheme_p2: Some(Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 }),
        codec: PayloadCodec::Raw,
    };
    let workers = 5;
    let n = 1200;
    let specs: Vec<RoundSpec> = vec![
        base.with_levels(3).unwrap(),
        base.with_levels(7).unwrap(),
        RoundSpec { codec: PayloadCodec::Huffman, ..base.with_levels(15).unwrap() },
        base.with_levels(7).unwrap(), // revisit an earlier spec
    ];
    let mut session = Session::new(&base.worker_schemes(workers), 31, n).unwrap();
    let mut rng = Xoshiro256::new(0xD44);
    // expected per-spec sums, accumulated from encode-time metrics
    let mut expect: std::collections::BTreeMap<String, (u64, f64, f64)> =
        std::collections::BTreeMap::new();
    const PERMS: usize = 6;

    for (round, spec) in specs.iter().enumerate() {
        let round = round as u64;
        session.apply_spec(spec).unwrap();
        let schemes = spec.worker_schemes(workers);
        let gs = correlated_grads(n, workers, 7000 + round);
        let msgs: Vec<WorkerMsg> = gs
            .iter()
            .enumerate()
            .map(|(p, g)| {
                let mut q = schemes[p].build();
                let stream = DitherStream::new(31, p as u32);
                let wire = q.encode_coded(g, &mut stream.round(round), spec.codec);
                WorkerMsg::new(p, round, 0.0, wire)
            })
            .collect();
        let reference = RefServer::new(&schemes, 31, n).decode_round(&msgs).unwrap();
        for _ in 0..PERMS {
            let order = shuffled(msgs.len(), &mut rng);
            assert_permutation_matches(&mut session, &msgs, &order, &reference);
        }
        // every permutation re-billed the round's messages into this
        // spec's lane
        let lane = expect.entry(spec.label()).or_insert((0, 0.0, 0.0));
        for m in &msgs {
            lane.0 += PERMS as u64;
            lane.1 += PERMS as f64 * m.metrics.transmitted_bits as f64;
            lane.2 += PERMS as f64 * m.metrics.raw_bits as f64;
        }
    }

    let stats = session.stats();
    assert_eq!(stats.per_spec.len(), 3, "{:?}", stats.per_spec.keys());
    for (label, (msgs, tx, raw)) in &expect {
        let lane = stats
            .per_spec
            .get(label)
            .unwrap_or_else(|| panic!("no ledger lane for spec `{label}`"));
        assert_eq!(lane.messages, *msgs, "{label}");
        assert_eq!(lane.transmitted_bits, *tx, "{label}");
        assert_eq!(lane.raw_bits, *raw, "{label}");
    }
    // and the lanes sum to the ledger totals exactly
    let lane_msgs: u64 = stats.per_spec.values().map(|l| l.messages).sum();
    let lane_tx: f64 = stats.per_spec.values().map(|l| l.transmitted_bits).sum();
    assert_eq!(lane_msgs, stats.messages);
    assert_eq!(lane_tx, stats.total_transmitted_bits);
    // the huffman-coded 15-level round genuinely shipped below its
    // raw-equivalent rate
    let coded = &stats.per_spec[&specs[2].label()];
    assert!(coded.transmitted_bits < coded.raw_bits);
}

#[test]
fn prop_mixed_spec_rounds_fold_bit_identically_under_error_feedback() {
    // The EF extension of the mixed-spec property: each worker owns one
    // persistent `EfState` whose residual lanes survive every
    // `apply_spec` re-leveling (identity carry, gradient units), and the
    // session must still fold every EF-encoded round bit-identically to
    // the verbatim reference under any arrival permutation. A shadow
    // replica of the carry recurrence (`lane = (lane + g) - recon`,
    // recon taken from an independent payload-bytes decode) pins the
    // telescoping-sum invariant end to end, bit for bit.
    let base = RoundSpec {
        scheme: Scheme::Nuqsgd { m: 3 },
        scheme_p2: None,
        codec: PayloadCodec::Raw,
    };
    let workers = 4;
    let n = 1100;
    let specs: Vec<RoundSpec> = vec![
        base.with_levels(7).unwrap(),
        base.with_levels(15).unwrap(), // re-leveled mid-run: lanes carry over
        RoundSpec { codec: PayloadCodec::Huffman, ..base.with_levels(5).unwrap() },
        base.with_levels(7).unwrap(), // revisit the opening spec
    ];
    let mut session = Session::new(&base.worker_schemes(workers), 77, n).unwrap();
    let mut rng = Xoshiro256::new(0xE55);
    let mut efs: Vec<EfState> = (0..workers).map(|_| EfState::new()).collect();
    let mut shadow = vec![vec![0f32; n]; workers];

    for (round, spec) in specs.iter().enumerate() {
        let round = round as u64;
        session.apply_spec(spec).unwrap();
        let schemes = spec.worker_schemes(workers);
        let gs = correlated_grads(n, workers, 9000 + round);
        let msgs: Vec<WorkerMsg> = gs
            .iter()
            .enumerate()
            .map(|(p, g)| {
                let mut q = schemes[p].build();
                let stream = DitherStream::new(77, p as u32);
                let wire = efs[p]
                    .encode_coded(q.as_mut(), g, &mut stream.round(round), spec.codec)
                    .unwrap();
                WorkerMsg::new(p, round, 0.0, wire)
            })
            .collect();
        let reference = RefServer::new(&schemes, 77, n).decode_round(&msgs).unwrap();
        for _ in 0..6 {
            let order = shuffled(msgs.len(), &mut rng);
            assert_permutation_matches(&mut session, &msgs, &order, &reference);
        }
        // shadow carry: same f32 op order as the EF lane, recon re-derived
        // from the transport bytes alone
        let registry = SchemeRegistry::from_schemes(&schemes).unwrap();
        for (p, msg) in msgs.iter().enumerate() {
            let stream = DitherStream::new(77, p as u32);
            let recon = registry
                .decode(&msg.wire, &mut stream.round(round), None)
                .unwrap();
            for ((s, &gi), &ri) in shadow[p].iter_mut().zip(&gs[p]).zip(&recon) {
                let v = *s + gi;
                *s = v - ri;
            }
        }
        for (p, ef) in efs.iter().enumerate() {
            assert_eq!(
                ef.residual(),
                &shadow[p][..],
                "worker {p}: EF lane diverged from the telescoping shadow after {}",
                spec.label()
            );
        }
    }
    // the carry is genuinely alive: lossy quantization leaves residue
    for (p, ef) in efs.iter().enumerate() {
        assert!(
            ef.residual().iter().any(|&r| r != 0.0),
            "worker {p}: residual lane identically zero"
        );
    }
}

#[test]
fn aggregator_and_reference_agree_on_bootstrap_failure() {
    // a round carrying only P2 messages must fail in both implementations
    let schemes = vec![
        Scheme::Dithered { delta: 1.0 / 3.0 },
        Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
        Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
    ];
    let n = 300;
    let gs = correlated_grads(n, schemes.len(), 4);
    let msgs = make_msgs(&schemes, &gs, 2, 0, 1);
    let p2_only: Vec<WorkerMsg> = msgs[1..].to_vec();
    assert!(RefServer::new(&schemes, 2, n).decode_round(&p2_only).is_err());
    let mut session = Session::new(&schemes, 2, n).unwrap();
    assert!(session.decode_round(&p2_only).is_err());
    // and the very next full round on the same session succeeds
    let reference = RefServer::new(&schemes, 2, n).decode_round(&msgs).unwrap();
    assert_eq!(session.decode_round(&msgs).unwrap(), reference);
}
