//! Integration tests over the full stack: trainer rounds through the real
//! PJRT runtime on the FC model. Skipped (with a notice) if `make
//! artifacts` hasn't run.

use ndq::config::{OptKind, TrainConfig};
use ndq::quant::Scheme;
use ndq::train::Trainer;

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn short_cfg(scheme: Scheme, workers: usize, rounds: usize) -> TrainConfig {
    TrainConfig {
        model: "fc300".into(),
        workers,
        scheme,
        rounds,
        eval_every: rounds,
        eval_examples: 512,
        seed: 1234,
        ..TrainConfig::default()
    }
}

#[test]
fn dqsg_training_learns() {
    if !have_artifacts() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    let mut t = Trainer::new(short_cfg(Scheme::Dithered { delta: 1.0 }, 4, 40)).unwrap();
    let (loss0, acc0) = t.evaluate().unwrap();
    let report = t.run().unwrap();
    assert!(report.final_eval_loss < loss0, "loss did not drop");
    assert!(report.final_accuracy > acc0, "accuracy did not improve");
    // Table-1 bits for ternary DQSG on FC-300-100
    let kbits = report.comm.kbits_per_msg_raw();
    assert!((kbits - 426.6).abs() < 1.0, "raw bits {kbits}");
}

#[test]
fn same_seed_is_bit_deterministic() {
    if !have_artifacts() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    let run = || {
        let mut t = Trainer::new(short_cfg(Scheme::Dithered { delta: 0.5 }, 2, 10)).unwrap();
        let r = t.run().unwrap();
        (r.final_eval_loss, t.params().to_vec())
    };
    let (l1, p1) = run();
    let (l2, p2) = run();
    assert_eq!(l1, l2);
    assert_eq!(p1, p2, "trained parameters not bit-deterministic");
}

#[test]
fn different_seed_differs() {
    if !have_artifacts() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    let mut cfg = short_cfg(Scheme::Dithered { delta: 0.5 }, 2, 6);
    let mut t1 = Trainer::new(cfg.clone()).unwrap();
    let r1 = t1.run().unwrap();
    cfg.seed = 999;
    let mut t2 = Trainer::new(cfg).unwrap();
    let r2 = t2.run().unwrap();
    assert_ne!(t1.params(), t2.params());
    // but both should learn comparably
    assert!((r1.final_eval_loss - r2.final_eval_loss).abs() < 0.5);
}

#[test]
fn ndqsg_mixed_groups_run_and_match_dqsg_bits_claim() {
    if !have_artifacts() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    // Fig. 6 setup on a short run: 4 workers, 2 DQSG(0.5) + 2 NDQSG(1/3, 1)
    let mut cfg = short_cfg(Scheme::Dithered { delta: 0.5 }, 4, 20);
    cfg.scheme_p2 = Some(Scheme::Nested {
        d1: 1.0 / 3.0,
        ratio: 3,
        alpha: 1.0,
    });
    let mut t = Trainer::new(cfg).unwrap();
    let report = t.run().unwrap();
    assert!(report.final_eval_loss.is_finite());
    // mixed run mean bits: (log2 5 + log2 3)/2 per coord ~ (619.2+422.8)/2
    let kbits = report.comm.kbits_per_msg_raw();
    assert!(
        (kbits - (619.2 + 426.6) / 2.0).abs() < 15.0,
        "mixed raw Kbits {kbits}"
    );
    // NDQSG training must actually learn (decode through side info works)
    assert!(report.final_accuracy > 0.12);
}

#[test]
fn all_schemes_complete_one_round() {
    if !have_artifacts() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    for scheme in [
        Scheme::Baseline,
        Scheme::Dithered { delta: 1.0 },
        Scheme::DitheredPartitioned { delta: 1.0, k: 4 },
        Scheme::Qsgd { m: 1 },
        Scheme::Terngrad,
        Scheme::OneBit,
    ] {
        let mut t = Trainer::new(short_cfg(scheme, 2, 2)).unwrap();
        let r = t.run().unwrap();
        assert!(r.final_eval_loss.is_finite(), "{:?}", scheme);
    }
}

#[test]
fn adam_runs_and_beats_initial_loss() {
    if !have_artifacts() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    let mut cfg = short_cfg(Scheme::Dithered { delta: 0.5 }, 4, 30);
    cfg.opt = OptKind::Adam;
    cfg.lr = 0.001;
    let mut t = Trainer::new(cfg).unwrap();
    let (loss0, _) = t.evaluate().unwrap();
    let r = t.run().unwrap();
    assert!(r.final_eval_loss < loss0);
}

#[test]
fn worker_count_scaling_shapes() {
    if !have_artifacts() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    // more workers, same total batch: bits per worker unchanged; total bits
    // scale linearly with P.
    let r2 = Trainer::new(short_cfg(Scheme::Dithered { delta: 1.0 }, 2, 5))
        .unwrap()
        .run()
        .unwrap();
    let r8 = Trainer::new(short_cfg(Scheme::Dithered { delta: 1.0 }, 8, 5))
        .unwrap()
        .run()
        .unwrap();
    assert!((r2.comm.kbits_per_msg_raw() - r8.comm.kbits_per_msg_raw()).abs() < 0.1);
    let total2 = r2.comm.total_raw_bits;
    let total8 = r8.comm.total_raw_bits;
    assert!((total8 / total2 - 4.0).abs() < 0.05, "{}", total8 / total2);
}
