//! Integration tests for the paper's "future work" extensions we built:
//! bounded-staleness asynchronous training and hierarchical (two-tier)
//! nested aggregation.

use ndq::config::TrainConfig;
use ndq::quant::Scheme;
use ndq::train::hierarchy::{aggregate_round, true_mean, Hierarchy};
use ndq::train::AsyncTrainer;

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[test]
fn async_trainer_rejects_ndqsg_at_construction() {
    // needs no artifacts: the scheme check fires before the compute
    // service starts. NDQSG decode needs Alg.-2 side information, which
    // only a synchronous round can bootstrap — the async trainer must say
    // so up front instead of mis-decoding with side = None at runtime.
    let cfg = TrainConfig {
        scheme: Scheme::Nested {
            d1: 1.0 / 3.0,
            ratio: 3,
            alpha: 1.0,
        },
        ..TrainConfig::default()
    };
    let err = match AsyncTrainer::new(cfg, 2) {
        Ok(_) => panic!("NDQSG must be rejected by the async trainer"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("side information"), "{err}");

    // the P2 group split is a synchronous concept too
    let cfg = TrainConfig {
        scheme_p2: Some(Scheme::Dithered { delta: 0.5 }),
        ..TrainConfig::default()
    };
    let err = match AsyncTrainer::new(cfg, 2) {
        Ok(_) => panic!("scheme_p2 must be rejected by the async trainer"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("single scheme"), "{err}");
}

#[test]
fn async_trainer_learns_with_dqsg() {
    if !have_artifacts() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    let cfg = TrainConfig {
        model: "fc300".into(),
        workers: 4,
        scheme: Scheme::Dithered { delta: 1.0 },
        rounds: 25,
        eval_every: 0,
        eval_examples: 512,
        seed: 5,
        ..TrainConfig::default()
    };
    let mut t = AsyncTrainer::new(cfg, 3).unwrap();
    let (report, stats) = t.run().unwrap();
    assert_eq!(stats.updates, 25 * 4);
    assert!(stats.max_staleness_seen <= 3);
    assert!(stats.mean_staleness > 0.0, "no asynchrony actually happened");
    assert!(report.final_accuracy > 0.15, "acc {}", report.final_accuracy);
    assert!(report.final_eval_loss.is_finite());
}

#[test]
fn async_strict_staleness_zero_still_progresses() {
    if !have_artifacts() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    let cfg = TrainConfig {
        model: "fc300".into(),
        workers: 3,
        scheme: Scheme::Dithered { delta: 1.0 },
        rounds: 5,
        eval_every: 0,
        eval_examples: 128,
        ..TrainConfig::default()
    };
    let mut t = AsyncTrainer::new(cfg, 0).unwrap();
    let (report, stats) = t.run().unwrap();
    assert_eq!(stats.max_staleness_seen, 0); // bound enforced by dropping
    assert!(report.final_eval_loss.is_finite());
}

#[test]
fn hierarchy_on_real_gradients() {
    if !have_artifacts() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    use ndq::data::{Batch, ImageDataset, ImageKind};
    use ndq::runtime::{ComputeService, Manifest};
    use std::sync::Arc;

    let svc = ComputeService::start(std::path::Path::new("artifacts")).unwrap();
    let h = svc.handle();
    let m = Manifest::load(std::path::Path::new("artifacts")).unwrap();
    let params = Arc::new(m.init_params("fc300").unwrap());
    let ds = ImageDataset::new(ImageKind::Mnist, 0);

    // 2 groups x 2 workers, each with its own data shard (real correlation)
    let mut grads = vec![vec![], vec![]];
    for w in 0..4usize {
        let mut batch = Batch::new(16, 784);
        ds.train_batch(0, w, 4, 16, &mut batch);
        let (_, g) = h.grad_image("fc300", &params, batch.x, batch.y, 16).unwrap();
        grads[w / 2].push(g);
    }
    let topo = Hierarchy::paper_default(2, 2);
    let round = aggregate_round(&topo, &grads, 42, 0).unwrap();
    let want = true_mean(&grads);
    let rmse = (ndq::tensor::sq_dist(&round.average, &want) / want.len() as f64).sqrt();
    let kappa = ndq::tensor::linf_norm(&want);
    assert!(
        rmse < 0.5 * kappa as f64,
        "hierarchical aggregate too far from true mean: rmse {rmse} (kappa {kappa})"
    );
    assert!(round.leaf_bits < round.flat_dqsg_bits);
}
