//! Level-policy acceptance suite: per-round adaptive quantization through
//! the shared round-plan engine, run on the artifact-free cluster harness.
//!
//! Pins the ISSUE-5 satellite claims:
//! * determinism — same seed + same policy => bit-identical
//!   `TrainReport::fingerprint()` (and the underlying fields);
//! * economy — `schedule` and `norm-adaptive` runs transmit strictly fewer
//!   bits than a fixed run at the largest level count they visit;
//! * equivalence — a constant one-point schedule is bit-identical (modulo
//!   the config label) to the fixed run at that k.

use ndq::comm::RoundSpec;
use ndq::quant::{PayloadCodec, Scheme};
use ndq::testing::cluster::{run_scenario, ClusterScenario};
use ndq::train::LevelPolicy;

fn scenario(levels: LevelPolicy) -> ClusterScenario {
    ClusterScenario {
        workers: 6,
        n_params: 3000,
        rounds: 40,
        seed: 1234,
        scheme: Scheme::Dithered { delta: 1.0 / 3.0 },
        scheme_p2: Some(Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 }),
        levels_policy: levels,
        eval_every: 10,
        ..ClusterScenario::default()
    }
}

#[test]
fn same_seed_same_policy_bit_identical_fingerprint() {
    for levels in [
        LevelPolicy::parse("schedule:0=15,10=7,25=3").unwrap(),
        LevelPolicy::parse("norm-adaptive:3:15").unwrap(),
    ] {
        let a = run_scenario(scenario(levels.clone())).unwrap();
        let b = run_scenario(scenario(levels.clone())).unwrap();
        assert_eq!(
            a.fingerprint(),
            b.fingerprint(),
            "{}: same seed + policy must be bit-identical",
            levels.label()
        );
        assert_eq!(a.delivery, b.delivery);
        assert_eq!(
            a.comm.total_transmitted_bits.to_bits(),
            b.comm.total_transmitted_bits.to_bits()
        );
        assert_eq!(a.comm.per_spec, b.comm.per_spec);
        assert_eq!(a.final_eval_loss.to_bits(), b.final_eval_loss.to_bits());
        // a different seed moves the trajectory (and hence the digest)
        let mut other = scenario(levels.clone());
        other.seed = 4321;
        let c = run_scenario(other).unwrap();
        assert_ne!(a.fingerprint(), c.fingerprint());
    }
}

#[test]
fn error_feedback_runs_are_deterministic_across_releveling() {
    // The EF lane extension of the determinism pin: residual carries are
    // worker state *outside* the per-spec encoder rebuilds, so a schedule
    // that re-levels mid-run must stay bit-identical across repeats with
    // EF enabled — and the `ef=on` label is part of the fingerprint, so
    // an EF run can never be mistaken for its EF-off twin.
    let ef = |levels: LevelPolicy| ClusterScenario {
        scheme_p2: None, // NDQSG needs side info and cannot run under EF
        error_feedback: true,
        ..scenario(levels)
    };
    let policy = LevelPolicy::parse("schedule:0=15,10=7,25=3").unwrap();
    let a = run_scenario(ef(policy.clone())).unwrap();
    let b = run_scenario(ef(policy.clone())).unwrap();
    assert_eq!(
        a.fingerprint(),
        b.fingerprint(),
        "same seed + policy + EF must be bit-identical"
    );
    assert_eq!(a.comm.per_spec, b.comm.per_spec);
    assert_eq!(a.final_eval_loss.to_bits(), b.final_eval_loss.to_bits());
    // three specs visited — the lanes survived two re-levelings en route
    assert_eq!(a.comm.per_spec.len(), 3, "{:?}", a.comm.per_spec.keys());
    // EF-off twin: same schedule, different label, different digest
    let off = run_scenario(ClusterScenario {
        error_feedback: false,
        ..ef(policy.clone())
    })
    .unwrap();
    assert!(a.config_label.contains("ef=on"), "{}", a.config_label);
    assert!(!off.config_label.contains("ef=on"), "{}", off.config_label);
    assert_ne!(a.fingerprint(), off.fingerprint());
    // and the EF run still converges on the quadratic
    assert!(a.final_eval_loss < 0.05, "{}", a.final_eval_loss);
}

#[test]
fn adaptive_policies_transmit_strictly_less_than_largest_fixed_k() {
    // the largest k either adaptive run visits is 15; the fixed comparison
    // runs the whole training at that k
    let fixed_at_15 = ClusterScenario {
        scheme: Scheme::Dithered { delta: 1.0 / 3.0 }.with_levels(15).unwrap(),
        scheme_p2: Some(
            Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 }
                .with_levels(15)
                .unwrap(),
        ),
        ..scenario(LevelPolicy::Fixed)
    };
    let fixed = run_scenario(fixed_at_15).unwrap();
    assert_eq!(fixed.comm.per_spec.len(), 1);

    let sched = run_scenario(scenario(
        LevelPolicy::parse("schedule:0=15,10=7,25=3").unwrap(),
    ))
    .unwrap();
    assert!(
        sched.comm.total_transmitted_bits < fixed.comm.total_transmitted_bits,
        "schedule {} vs fixed {}",
        sched.comm.total_transmitted_bits,
        fixed.comm.total_transmitted_bits
    );
    assert_eq!(sched.comm.per_spec.len(), 3, "{:?}", sched.comm.per_spec.keys());

    let adaptive =
        run_scenario(scenario(LevelPolicy::parse("norm-adaptive:3:15").unwrap())).unwrap();
    assert!(
        adaptive.comm.total_transmitted_bits < fixed.comm.total_transmitted_bits,
        "norm-adaptive {} vs fixed {}",
        adaptive.comm.total_transmitted_bits,
        fixed.comm.total_transmitted_bits
    );
    // the quadratic contracts, so the norm rule genuinely visited more
    // than one level count (the whole point of the adaptive dial)
    assert!(
        adaptive.comm.per_spec.len() > 1,
        "{:?}",
        adaptive.comm.per_spec.keys()
    );
    // same message count on the clean link — the saving is per-bit, not
    // from hearing fewer workers
    assert_eq!(sched.comm.messages, fixed.comm.messages);
    assert_eq!(adaptive.comm.messages, fixed.comm.messages);
    // and both adaptive runs still converge on the quadratic
    assert!(sched.final_eval_loss < 0.05, "{}", sched.final_eval_loss);
    assert!(adaptive.final_eval_loss < 0.05, "{}", adaptive.final_eval_loss);
}

#[test]
fn constant_schedule_matches_fixed_run_bit_for_bit() {
    // schedule:0=7 re-levels Dithered(1/3) to... itself (7 levels: the
    // re-derived delta is the same f32 division 1.0/3.0), every round.
    // Everything except the config label must be bit-identical to the
    // fixed run — the engine refactor cannot have moved the math. Uniform
    // scheme: re-leveling would widen a mixed run's ratio-3 NDQSG half.
    let uniform = |levels: LevelPolicy| ClusterScenario {
        scheme_p2: None,
        ..scenario(levels)
    };
    let fixed = run_scenario(uniform(LevelPolicy::Fixed)).unwrap();
    let constant =
        run_scenario(uniform(LevelPolicy::parse("schedule:0=7").unwrap())).unwrap();
    assert_eq!(fixed.history.len(), constant.history.len());
    for (a, b) in fixed.history.iter().zip(&constant.history) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!(a.eval_loss.to_bits(), b.eval_loss.to_bits());
        assert_eq!(
            a.cum_transmitted_bits_per_worker.to_bits(),
            b.cum_transmitted_bits_per_worker.to_bits()
        );
    }
    assert_eq!(fixed.delivery, constant.delivery);
    assert_eq!(
        fixed.comm.total_transmitted_bits.to_bits(),
        constant.comm.total_transmitted_bits.to_bits()
    );
    assert_eq!(
        fixed.comm.total_raw_bits.to_bits(),
        constant.comm.total_raw_bits.to_bits()
    );
    // the ledger lane label differs (re-leveled Dithered prints its delta
    // differently only if the float differs — both are 1/3 exactly here),
    // but each run has exactly one lane with identical totals
    assert_eq!(fixed.comm.per_spec.len(), 1);
    assert_eq!(constant.comm.per_spec.len(), 1);
    let f = fixed.comm.per_spec.values().next().unwrap();
    let c = constant.comm.per_spec.values().next().unwrap();
    assert_eq!(f.messages, c.messages);
    assert_eq!(f.transmitted_bits.to_bits(), c.transmitted_bits.to_bits());
}

#[test]
fn norm_adaptive_degenerate_anchor_holds_previous_plan() {
    // A zero or non-finite anchor norm used to NaN-poison
    // `rho = ln / n0`, and the saturating `ceil() as i64` cast silently
    // pinned k to KMIN. The rule must instead hold the previous plan.
    let p = LevelPolicy::parse("norm-adaptive:3:15").unwrap();
    assert_eq!(p.k_for(4, Some(0.0), Some(3.0), Some(7)), Some(7));
    assert_eq!(p.k_for(4, Some(f64::NAN), Some(3.0), Some(9)), Some(9));
    assert_eq!(p.k_for(4, Some(f64::INFINITY), Some(3.0), Some(9)), Some(9));
    assert_eq!(p.k_for(4, Some(10.0), Some(f64::NAN), Some(5)), Some(5));
    // without a previous plan the rule starts at full resolution — never
    // the silent KMIN pin
    assert_eq!(p.k_for(4, Some(0.0), Some(3.0), None), Some(15));
    // healthy anchors are unaffected by the guard
    assert_eq!(p.k_for(4, Some(10.0), Some(10.0), Some(3)), Some(15));
}

#[test]
fn unrealizable_policy_is_a_setup_error() {
    // one-bit has no level dial
    let sc = ClusterScenario {
        scheme: Scheme::OneBit,
        scheme_p2: None,
        ..scenario(LevelPolicy::parse("schedule:0=3").unwrap())
    };
    assert!(ndq::testing::cluster::ClusterHarness::new(sc).is_err());
    // an aac run whose schedule visits an alphabet beyond the model
    // ceiling fails at build time, not round 20
    let spec = RoundSpec {
        scheme: Scheme::Dithered { delta: 1.0 / 3.0 },
        scheme_p2: None,
        codec: PayloadCodec::Aac,
    };
    assert!(spec.with_levels(65_535).is_err());
    assert!(spec.with_levels(15).is_ok());
}
