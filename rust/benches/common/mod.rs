#![allow(dead_code)]
//! Shared helpers for the paper-table benches.
//!
//! Each bench binary regenerates one table/figure of the paper. `rounds()`
//! scales workload to the environment: full fidelity by default, trimmed
//! under NDQ_BENCH_FAST=1 (CI) — the *shape* conclusions hold at both.

use std::sync::Arc;

use ndq::data::{Batch, ImageDataset, ImageKind};
use ndq::runtime::{ComputeHandle, ComputeService, Manifest};

pub fn fast() -> bool {
    std::env::var("NDQ_BENCH_FAST").is_ok()
}

/// Scale a round budget for the environment.
pub fn rounds(full: usize) -> usize {
    if fast() {
        (full / 10).max(3)
    } else {
        full
    }
}

pub fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

pub fn skip_or_panic() -> bool {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built — run `make artifacts` first");
        return true;
    }
    false
}

/// A real gradient for `model` computed through the AOT artifact at init.
pub fn real_gradient(model: &str) -> ndq::Result<Vec<f32>> {
    let svc = ComputeService::start(std::path::Path::new("artifacts"))?;
    let h = svc.handle();
    let m = Manifest::load(std::path::Path::new("artifacts"))?;
    let params = Arc::new(m.init_params(model)?);
    gradient_at(&h, model, &params, 0)
}

/// Gradient for `model` at the given params/round through a live handle.
pub fn gradient_at(
    h: &ComputeHandle,
    model: &str,
    params: &Arc<Vec<f32>>,
    round: u64,
) -> ndq::Result<Vec<f32>> {
    let kind = ImageKind::for_model(model)?;
    let ds = ImageDataset::new(kind, 0);
    let b = 32;
    let mut batch = Batch::new(b, kind.feature_dim());
    ds.train_batch(round, 0, 1, b, &mut batch);
    let (_, g) = h.grad_image(model, params, batch.x, batch.y, b)?;
    Ok(g)
}

/// Write bench rows as JSON lines for EXPERIMENTS.md extraction.
pub fn save_json(file: &str, j: ndq::util::json::Json) {
    let dir = std::path::Path::new("target/ndq-bench");
    let _ = std::fs::create_dir_all(dir);
    let _ = std::fs::write(dir.join(file), j.to_string());
    println!("[saved target/ndq-bench/{file}]");
}
