//! Table 2: per-worker bits after entropy coding at 32 workers.
//!
//! The paper trains with 32 workers and reports the entropy-coded stream
//! size mid-training. We do the same: train FC-300-100 with 32 workers for
//! a short burst (so gradients have realistic sparseness), then for each
//! scheme encode the *current* per-worker gradients and report (a) the
//! order-0 entropy limit and (b) the actual adaptive-arithmetic-coder
//! output. LeNet / CifarNet rows use the same trained-gradient methodology
//! at smaller round budgets (their artifacts are slower per step).
//!
//! Shape under test (paper Table 2): DQSGD ~ QSGD < TernGrad << One-Bit,
//! with One-Bit nearly incompressible.

mod common;

use ndq::config::TrainConfig;
use ndq::prng::DitherStream;
use ndq::quant::{GradQuantizer, Scheme};
use ndq::stats::bench::{print_table_header, print_table_row};
use ndq::train::Trainer;
use ndq::util::json::{self, Json};

const PAPER: &[(&str, [f64; 4])] = &[
    ("fc300", [38.6, 38.2, 48.23, 330.0]),
    ("lenet", [299.7, 307.3, 438.2, 1889.0]),
    ("cifarnet", [192.7, 197.0, 281.0, 1241.0]),
];

fn main() -> ndq::Result<()> {
    if common::skip_or_panic() {
        return Ok(());
    }
    let schemes = [
        ("DQSGD", Scheme::Dithered { delta: 1.0 }),
        ("QSGD", Scheme::Qsgd { m: 1 }),
        ("TernGrad", Scheme::Terngrad),
        ("One-Bit", Scheme::OneBit),
    ];
    let mut rows = Vec::new();
    print_table_header(
        "Table 2 — entropy-coded Kbits per worker per iteration, 32 workers (AAC / paper)",
        &["DQSGD", "QSGD", "TernGrad", "One-Bit"],
    );
    for (model, paper_row) in PAPER {
        // short 32-worker training to reach realistic gradient statistics
        let rounds = match *model {
            "fc300" => common::rounds(30),
            _ => common::rounds(8),
        };
        let cfg = TrainConfig {
            model: model.to_string(),
            workers: 32,
            scheme: Scheme::Dithered { delta: 1.0 },
            rounds,
            eval_every: 0,
            eval_examples: 128,
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(cfg)?;
        let _ = trainer.run()?;
        // measure on a fresh gradient at the trained parameters
        let params = std::sync::Arc::new(trainer.params().to_vec());
        let h = trainer.compute();
        let grad = common::gradient_at(&h, model, &params, 10_000)?;

        let mut aac = Vec::new();
        let mut entropy = Vec::new();
        for (_, scheme) in &schemes {
            let mut q = scheme.build();
            let stream = DitherStream::new(2, 0);
            let msg = q.encode(&grad, &mut stream.round(0));
            aac.push(msg.aac_bits() as f64 / 1000.0);
            entropy.push(msg.entropy_bits() / 1000.0);
        }
        print_table_row(&format!("{model} (AAC)"), &aac);
        print_table_row(&format!("{model} (H lim)"), &entropy);
        print_table_row(&format!("{model} (paper)"), paper_row);

        // shape assertions
        assert!(
            (aac[0] - aac[1]).abs() < 0.25 * aac[0].max(aac[1]),
            "{model}: DQSGD and QSGD should compress similarly"
        );
        assert!(aac[3] > 2.0 * aac[0], "{model}: One-Bit must be far less compressible");
        // AAC within ~5% of the entropy limit (paper's claim), scales excluded
        for (a, h) in aac.iter().zip(&entropy) {
            assert!(a / h < 1.06, "{model}: AAC {a:.1} vs entropy {h:.1}");
        }
        rows.push(json::obj(vec![
            ("model", json::s(model)),
            ("aac_kbits", json::f32s(&aac.iter().map(|&x| x as f32).collect::<Vec<_>>())),
            (
                "entropy_kbits",
                json::f32s(&entropy.iter().map(|&x| x as f32).collect::<Vec<_>>()),
            ),
            (
                "paper_kbits",
                json::f32s(&paper_row.iter().map(|&x| x as f32).collect::<Vec<_>>()),
            ),
        ]));
    }
    println!("\nshape checks passed: DQSGD ~ QSGD < TernGrad << One-Bit; AAC within ~5% of entropy");
    common::save_json("table2.json", Json::Arr(rows));
    Ok(())
}
