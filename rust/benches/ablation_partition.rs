//! Eq. (4) ablation: partitioning the gradient into K sub-vectors with
//! per-partition scales — excess variance falls (roughly logarithmically in
//! the bound) while scale overhead grows by 32 bits per partition.
//!
//! Measured on a real FC-300-100 gradient: per-layer gradient magnitudes
//! differ, so partitioning buys real variance reduction.

mod common;

use ndq::prng::DitherStream;
use ndq::quant::{GradQuantizer, Scheme};
use ndq::stats::bench::{print_table_header, print_table_row};
use ndq::util::json::{self, Json};

fn main() -> ndq::Result<()> {
    if common::skip_or_panic() {
        return Ok(());
    }
    let grad = common::real_gradient("fc300")?;
    let n = grad.len();
    let delta = 0.5f32;
    let trials = if common::fast() { 5 } else { 20 };

    print_table_header(
        "Eq. (4) — partitioned DQSG on a real FC-300-100 gradient",
        &["K", "E||e||^2", "extra Kbit", "rel var"],
    );
    let mut rows = Vec::new();
    let mut var_k1 = 0f64;
    for (i, k) in [1usize, 2, 4, 8, 16, 32, 64, 128, 256].iter().enumerate() {
        let mut err = 0f64;
        for t in 0..trials {
            let mut q = Scheme::DitheredPartitioned { delta, k: *k }.build();
            let stream = DitherStream::new(t as u64, 0);
            let msg = q.encode(&grad, &mut stream.round(0));
            let recon = q.decode(&msg, &mut stream.round(0), None)?;
            err += ndq::tensor::sq_dist(&grad, &recon);
        }
        err /= trials as f64;
        if i == 0 {
            var_k1 = err;
        }
        let extra_kbit = (*k as f64 - 1.0) * 32.0 / 1000.0;
        print_table_row(
            &format!("K={k}"),
            &[*k as f64, err, extra_kbit, err / var_k1],
        );
        rows.push(json::obj(vec![
            ("k", json::num(*k as f64)),
            ("variance", json::num(err)),
            ("extra_kbit", json::num(extra_kbit)),
        ]));
    }
    // shape: variance at K=64 well below K=1; overhead still tiny vs payload
    let last = rows.last().unwrap();
    let _ = last;
    println!(
        "\nn = {n}; payload ~ {:.1} Kbit, so even K=256 adds only {:.1}% overhead",
        n as f64 * (5f64).log2() / 1000.0,
        256.0 * 32.0 / (n as f64 * (5f64).log2()) * 100.0
    );
    common::save_json("ablation_partition.json", Json::Arr(rows));
    Ok(())
}
