//! Entropy-coder ablation: adaptive arithmetic coding (the paper's choice)
//! vs canonical Huffman (refs [3], [4]) vs the base-k packer, on real
//! gradient index streams at several training stages.
//!
//! Shape under test: AAC lands within ~5% of the stream entropy everywhere;
//! Huffman is pinned at >= 1 bit/symbol (ternary alphabet) so it loses
//! badly on peaked mid-training streams; the packer is constant-rate.

mod common;

use ndq::coding::{arithmetic, huffman, pack};
use ndq::config::TrainConfig;
use ndq::prng::DitherStream;
use ndq::quant::{GradQuantizer, Scheme};
use ndq::stats::bench::{print_table_header, print_table_row};
use ndq::train::Trainer;
use ndq::util::json::{self, Json};

fn main() -> ndq::Result<()> {
    if common::skip_or_panic() {
        return Ok(());
    }
    // gradients at three training stages: init, short, longer
    let stages = [(0usize, "init"), (common::rounds(20), "early"), (common::rounds(60), "mid")];
    print_table_header(
        "Entropy coders on real DQSG index streams (Kbit, fc300)",
        &["entropy", "AAC", "Huffman", "pack(k=3)"],
    );
    let mut rows = Vec::new();
    for (rounds, label) in stages {
        let grad = if rounds == 0 {
            common::real_gradient("fc300")?
        } else {
            let cfg = TrainConfig {
                model: "fc300".into(),
                workers: 8,
                scheme: Scheme::Dithered { delta: 1.0 },
                rounds,
                eval_every: 0,
                eval_examples: 128,
                ..TrainConfig::default()
            };
            let mut t = Trainer::new(cfg)?;
            let _ = t.run()?;
            let params = std::sync::Arc::new(t.params().to_vec());
            common::gradient_at(&t.compute(), "fc300", &params, 99_999)?
        };
        let mut q = Scheme::Dithered { delta: 1.0 }.build();
        let stream = DitherStream::new(5, 0);
        let msg = q.encode(&grad, &mut stream.round(0));

        let indices = msg.indices()?; // stats accessor: re-derived from payload
        let h_bits = msg.entropy_bits() - 32.0; // exclude the scale
        let aac = arithmetic::encoded_bits_signed(&indices, 1) as f64;
        let huff = huffman::encoded_bits_signed(&indices, 1) as f64;
        let packed = pack::packed_bits(indices.len(), 3) as f64;
        print_table_row(
            label,
            &[h_bits / 1000.0, aac / 1000.0, huff / 1000.0, packed / 1000.0],
        );
        assert!(aac / h_bits < 1.05, "{label}: AAC off entropy by {}", aac / h_bits);
        assert!(huff >= indices.len() as f64, "{label}: Huffman below 1 bit/sym?");
        rows.push(json::obj(vec![
            ("stage", json::s(label)),
            ("entropy_bits", json::num(h_bits)),
            ("aac_bits", json::num(aac)),
            ("huffman_bits", json::num(huff)),
            ("packed_bits", json::num(packed)),
        ]));
    }
    println!("\nshape check passed: AAC within 5% of entropy; Huffman floor-limited at 1 bit/sym");
    common::save_json("ablation_entropy_coders.json", Json::Arr(rows));
    Ok(())
}
