//! Thm. 6 ablation: NDQSG decoding-failure probability vs the eq. (8)
//! bound, and error variance vs the eq. (9) prediction, across the
//! side-information noise sigma_z, the coarse/fine ratio, and alpha.

mod common;

use ndq::prng::{DitherStream, Xoshiro256};
use ndq::quant::nested::NestedQuantizer;
use ndq::quant::GradQuantizer;
use ndq::stats::bench::{print_table_header, print_table_row};
use ndq::util::json::{self, Json};

fn main() -> ndq::Result<()> {
    let n = if common::fast() { 20_000 } else { 200_000 };
    let d1 = 1.0f32 / 3.0;
    print_table_header(
        "Thm. 6 — failure prob (measured vs eq. 8) and variance (vs eq. 9)",
        &["p_fail", "eq.(8)", "var", "eq.(9)"],
    );
    let mut rows = Vec::new();
    for (ratio, alpha, sigma_z) in [
        (3u32, 1.0f32, 0.05f32),
        (3, 1.0, 0.10),
        (3, 1.0, 0.15),
        (3, 1.0, 0.20),
        (5, 1.0, 0.20),
        (9, 1.0, 0.20),
        (3, 0.9, 0.10),
        (3, 0.75, 0.10),
    ] {
        let mut rng = Xoshiro256::new(42 + ratio as u64);
        // normalized-units experiment (kappa = 1): x in [-1, 1]
        let x: Vec<f32> = (0..n).map(|_| (rng.next_normal() * 0.3).clamp(-1.0, 1.0)).collect();
        // make |x|max exactly 1 so kappa = 1 and sigma_z is in x-units
        let mut x = x;
        x[0] = 1.0;
        let y: Vec<f32> = x.iter().map(|&v| v + sigma_z * rng.next_normal()).collect();
        let mut q = NestedQuantizer::new(d1, ratio, alpha);
        let stream = DitherStream::new(7, 0);
        let msg = q.encode(&x, &mut stream.round(0));
        let xh = q.decode(&msg, &mut stream.round(0), Some(&y))?;

        // failure = outside the exact-decode bound (wrong coarse bin)
        let exact_bound = alpha * d1 / 2.0 + (1.0 - alpha * alpha) * 4.0 * sigma_z;
        let fails = x
            .iter()
            .zip(&xh)
            .filter(|(a, b)| (**a - **b).abs() > exact_bound + 1e-5)
            .count();
        let p_fail = fails as f64 / n as f64;
        let bound = q.failure_bound(sigma_z as f64);
        let var = ndq::tensor::sq_dist(&x, &xh) / n as f64;
        let var_pred = q.exact_variance((sigma_z as f64).powi(2));

        print_table_row(
            &format!("k={ratio},a={alpha},s={sigma_z}"),
            &[p_fail, bound, var, var_pred],
        );
        rows.push(json::obj(vec![
            ("ratio", json::num(ratio as f64)),
            ("alpha", json::num(alpha as f64)),
            ("sigma_z", json::num(sigma_z as f64)),
            ("p_fail", json::num(p_fail)),
            ("bound", json::num(bound)),
            ("var", json::num(var)),
            ("var_pred", json::num(var_pred)),
        ]));
        // eq. (8) must upper-bound the measured failure rate
        assert!(
            p_fail <= bound + 0.01,
            "failure {p_fail} exceeds bound {bound} at k={ratio} a={alpha} s={sigma_z}"
        );
        // variance prediction valid when failures are rare
        if p_fail < 0.002 {
            assert!(
                (var - var_pred).abs() < 0.35 * var_pred.max(1e-6),
                "variance {var} vs predicted {var_pred}"
            );
        }
    }
    println!("\nshape checks passed: eq. (8) bounds p_fail; eq. (9) predicts variance in the exact regime");
    common::save_json("ablation_theorem6.json", Json::Arr(rows));
    Ok(())
}
