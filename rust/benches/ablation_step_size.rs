//! Thm. 5 / Eq. (5) ablation: iteration-count inflation vs quantization
//! step size Delta on a controlled convex problem.
//!
//! Eq. (5): (T - T_c) / T_c = (n Delta^2 / 12)(1 + B/V) where T_c is the
//! unquantized iteration count to reach epsilon. We minimize a quadratic
//! with synthetic stochastic gradients (variance V known by construction),
//! run DQSGD to a fixed loss threshold, and compare measured inflation with
//! the bound across Delta in {1, 1/2, 1/4, 1/8}.

mod common;

use ndq::prng::{DitherStream, Xoshiro256};
use ndq::quant::{GradQuantizer, Scheme};
use ndq::stats::bench::{print_table_header, print_table_row};
use ndq::util::json::{self, Json};

/// Rounds of DQSGD (P=1) until 0.5*||x - c||^2 <= eps; synthetic SG noise
/// sigma. Returns the iteration count.
fn rounds_to_eps(delta: Option<f32>, n: usize, sigma: f32, eps: f64, seed: u64) -> usize {
    let mut rng = Xoshiro256::new(seed);
    let c: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
    let mut x = vec![0f32; n];
    // Thm.-5-style tuned constant step: eta = eps / (eps*l + 1.1*sigma_eff^2)
    // with l = 1 on the quadratic and sigma_eff^2 the DQSG-inflated SG
    // variance (V = n sigma^2; the kappa^2 n D^2/12 term uses kappa ~ the
    // gradient linf scale in the terminal region, order sqrt(2 eps) + 3 sigma).
    let v = n as f64 * (sigma as f64).powi(2);
    let sigma_eff2 = match delta {
        None => v,
        Some(d) => {
            let kappa = (2.0 * eps).sqrt() + 3.0 * sigma as f64;
            v + kappa * kappa * n as f64 * (d as f64).powi(2) / 12.0
        }
    };
    let lr = (eps / (eps + 1.1 * sigma_eff2)).clamp(1e-5, 0.2) as f32;
    let mut quant = delta.map(|d| Scheme::Dithered { delta: d }.build());
    let stream = DitherStream::new(seed ^ 0xABCD, 0);
    for t in 0..200_000u64 {
        let loss: f64 = 0.5 * ndq::tensor::sq_dist(&x, &c);
        if loss <= eps {
            return t as usize;
        }
        // stochastic gradient: (x - c) + noise
        let g: Vec<f32> = x
            .iter()
            .zip(&c)
            .map(|(xi, ci)| (xi - ci) + sigma * rng.next_normal())
            .collect();
        let g = match &mut quant {
            Some(q) => {
                let msg = q.encode(&g, &mut stream.round(t));
                q.decode(&msg, &mut stream.round(t), None).unwrap()
            }
            None => g,
        };
        for (xi, gi) in x.iter_mut().zip(&g) {
            *xi -= lr * gi;
        }
    }
    200_000
}

fn main() -> ndq::Result<()> {
    let n = 64usize;
    let sigma = 0.3f32;
    let eps = 0.05f64;
    let trials = if common::fast() { 3 } else { 10 };

    let avg_rounds = |delta: Option<f32>| -> f64 {
        (0..trials)
            .map(|t| rounds_to_eps(delta, n, sigma, eps, 1000 + t as u64) as f64)
            .sum::<f64>()
            / trials as f64
    };

    let t_c = avg_rounds(None);
    print_table_header(
        &format!("Eq. (5) — DQSGD iteration inflation vs Delta (n={n}, T_c={t_c:.0})"),
        &["Delta", "T", "measured infl", "eq.(5) bound"],
    );
    let mut rows = Vec::new();
    let mut prev_inflation = f64::INFINITY;
    for delta in [1.0f32, 0.5, 0.25, 0.125] {
        let t_q = avg_rounds(Some(delta));
        let measured = (t_q - t_c) / t_c;
        // eq. (5) with the Thm.-5 tuned step: (T - T_c)/T_c =
        // (sigma_eff^2 - V)/V = kappa^2 n D^2 / (12 V), kappa the terminal
        // gradient scale (same estimate the tuned lr uses).
        let v = (n as f32 * sigma * sigma) as f64;
        let kappa = (2.0 * eps).sqrt() + 3.0 * sigma as f64;
        let bound = kappa * kappa * (n as f64) * (delta as f64).powi(2) / (12.0 * v);
        print_table_row(
            &format!("{delta}"),
            &[delta as f64, t_q, measured, bound],
        );
        rows.push(json::obj(vec![
            ("delta", json::num(delta as f64)),
            ("rounds", json::num(t_q)),
            ("measured_inflation", json::num(measured)),
            ("bound", json::num(bound)),
        ]));
        // shape: inflation decreases with Delta (quadratically per eq. 5)
        assert!(
            measured < prev_inflation + 0.10,
            "inflation should fall with Delta"
        );
        prev_inflation = measured;
    }
    println!("\nshape check passed: inflation shrinks ~Delta^2 (eq. 5)");
    common::save_json("ablation_step_size.json", Json::Arr(rows));
    Ok(())
}
