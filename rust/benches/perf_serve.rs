//! §Perf: event-loop serve scale — one leader thread driving 32/64/256
//! loopback UDS workers with the quantized delta downlink.
//!
//! The PR-10 tentpole replaced one-reader-thread-per-peer with a single
//! nonblocking sweep, so the leader's thread count is flat in the worker
//! count. This bench pins the scale story: every tier must complete with
//! zero failed rounds and zero disconnects, aggregate fold throughput
//! (messages/sec) must not collapse as peers multiply, and — at full
//! fidelity — 256 workers must sustain at least half the 32-worker
//! rounds/sec. Each tier's `{rounds_per_sec, downlink_kbits_per_round}`
//! row lands in target/ndq-bench/perf_serve.json, which tier1.sh appends
//! to the repo-root BENCH_wire.json trajectory.
//!
//! Workers here are threads (they model remote processes); the claim under
//! test is about the *leader*, which serves every peer from one sweep
//! loop regardless of tier.

mod common;

use std::time::Duration;

use ndq::comm::net::{NetAddr, NetListener};
use ndq::comm::DownlinkPolicy;
use ndq::quant::Scheme;
use ndq::testing::cluster::{serve_listener, worker_connect, ClusterScenario, ServeOptions};
use ndq::util::json::{self, Json};

struct Tier {
    workers: usize,
    rounds_per_sec: f64,
    downlink_kbits_per_round: f64,
    msgs_per_sec: f64,
}

fn run_tier(workers: usize, rounds: usize) -> ndq::Result<Tier> {
    let sc = ClusterScenario {
        workers,
        n_params: 512,
        rounds,
        eval_every: rounds,
        downlink: DownlinkPolicy::DeltaQuantized(Scheme::Dithered { delta: 1.0 / 3.0 }),
        ..ClusterScenario::default()
    };
    let path = std::env::temp_dir().join(format!(
        "ndq-{}-perf-serve-{workers}.sock",
        std::process::id()
    ));
    let listener = NetListener::bind(&NetAddr::Uds(path))?;
    let dial = listener.local_addr()?;
    let peers: Vec<_> = (0..workers)
        .map(|_| {
            let dial = dial.clone();
            std::thread::spawn(move || worker_connect(&dial, Duration::from_secs(60)))
        })
        .collect();
    let report = serve_listener(
        sc,
        listener,
        ServeOptions {
            io_timeout: Duration::from_secs(60),
        },
    )?;
    for p in peers {
        p.join().expect("worker thread panicked")?;
    }
    assert_eq!(report.rounds_failed, 0, "{workers}-worker tier failed rounds");
    assert_eq!(report.comm.disconnects, 0, "{workers}-worker tier lost peers");
    assert_eq!(report.comm.messages, (workers * rounds) as u64);
    let secs = report.wall_secs.max(1e-9);
    Ok(Tier {
        workers,
        rounds_per_sec: rounds as f64 / secs,
        downlink_kbits_per_round: report.comm.total_bcast_bits / 1000.0 / rounds as f64,
        msgs_per_sec: report.comm.messages as f64 / secs,
    })
}

fn main() -> ndq::Result<()> {
    let rounds = if common::fast() { 16 } else { 64 };
    let mut tiers = Vec::new();
    for &workers in &[32usize, 64, 256] {
        let t = run_tier(workers, rounds)?;
        println!(
            "serve/uds/{:>3}w  {:>8.1} rounds/s  {:>10.1} msgs/s  {:>8.2} downlink Kbit/round",
            t.workers, t.rounds_per_sec, t.msgs_per_sec, t.downlink_kbits_per_round
        );
        tiers.push(t);
    }

    let base = &tiers[0];
    let top = &tiers[tiers.len() - 1];
    let ratio = top.rounds_per_sec / base.rounds_per_sec;
    println!(
        "\n256w/32w rounds/sec ratio: {ratio:.3} (target >= 0.5), \
         msgs/sec ratio: {:.2}",
        top.msgs_per_sec / base.msgs_per_sec
    );
    // aggregate fold throughput must scale: 8x the peers may not collapse
    // the message rate below half the 32-worker tier's
    assert!(
        top.msgs_per_sec >= 0.5 * base.msgs_per_sec,
        "fold throughput collapsed at 256 workers: {:.0} msgs/s vs {:.0} at 32",
        top.msgs_per_sec,
        base.msgs_per_sec
    );
    if common::fast() {
        eprintln!("(fast mode: skipping the 0.5x rounds/sec shape assertion — \
                   the trimmed round budget under-amortizes the 256-way handshake)");
    } else {
        assert!(
            ratio >= 0.5,
            "256-worker tier sustains only {ratio:.3}x the 32-worker rounds/sec"
        );
    }

    let rows: Vec<Json> = tiers
        .iter()
        .map(|t| {
            json::obj(vec![
                ("name", json::s(&format!("serve/uds/{}w", t.workers))),
                ("workers", json::num(t.workers as f64)),
                ("rounds_per_sec", json::num(t.rounds_per_sec)),
                (
                    "downlink_kbits_per_round",
                    json::num(t.downlink_kbits_per_round),
                ),
                ("msgs_per_sec", json::num(t.msgs_per_sec)),
            ])
        })
        .collect();
    common::save_json("perf_serve.json", Json::Arr(rows));
    Ok(())
}
