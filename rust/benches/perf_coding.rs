//! §Perf: coding-layer throughput — base-k packing vs adaptive arithmetic
//! coding, and the dither PRNG fill rate (the three non-compute costs on
//! the wire path).

mod common;

use ndq::coding::{arithmetic, pack, BitReader, BitWriter};
use ndq::prng::{DitherStream, Xoshiro256};
use ndq::stats::bench::Bench;

fn main() -> ndq::Result<()> {
    let mut b = Bench::new();
    let n = 266_610usize;
    let mut rng = Xoshiro256::new(2);

    // gradient-index-like ternary stream, peaked at 0
    let symbols: Vec<u32> = (0..n)
        .map(|_| {
            let r = rng.next_f32();
            if r < 0.75 {
                1
            } else if r < 0.88 {
                0
            } else {
                2
            }
        })
        .collect();

    let r = b.run("pack_base3/266610", || {
        let mut w = BitWriter::new();
        pack::pack_base_k(&symbols, 3, &mut w);
        w
    });
    println!("    -> {:.1} M sym/s", r.throughput(n as f64) / 1e6);

    let mut w = BitWriter::new();
    pack::pack_base_k(&symbols, 3, &mut w);
    let packed = w.into_bytes();
    let r = b.run("unpack_base3/266610", || {
        let mut rd = BitReader::new(&packed);
        pack::unpack_base_k(&mut rd, 3, n).unwrap()
    });
    println!("    -> {:.1} M sym/s", r.throughput(n as f64) / 1e6);

    let r = b.run("aac_encode/266610", || {
        let mut w = BitWriter::new();
        arithmetic::encode(&symbols, 3, &mut w);
        w
    });
    println!("    -> {:.1} M sym/s", r.throughput(n as f64) / 1e6);

    let mut w = BitWriter::new();
    arithmetic::encode(&symbols, 3, &mut w);
    let coded = w.into_bytes();
    let r = b.run("aac_decode/266610", || {
        let mut rd = BitReader::new(&coded);
        arithmetic::decode(&mut rd, 3, n).unwrap()
    });
    println!("    -> {:.1} M sym/s", r.throughput(n as f64) / 1e6);

    // dither generation (Philox fill)
    let mut buf = vec![0f32; n];
    let stream = DitherStream::new(0, 0);
    let mut round = 0u64;
    let r = b.run("philox_fill_dither/266610", || {
        round += 1;
        stream.round(round).fill_dither(0.5, &mut buf);
        buf[0]
    });
    println!("    -> {:.1} M dithers/s", r.throughput(n as f64) / 1e6);

    b.save("perf_coding")?;
    Ok(())
}
