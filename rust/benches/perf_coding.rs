//! §Perf: coding-layer throughput — base-k packing vs the on-wire entropy
//! coders (Huffman + adaptive arithmetic), the adaptive model's
//! cumulative-count structure (Fenwick vs the old linear scan), and the
//! dither PRNG fill rate: the non-compute costs on the wire path.

mod common;

use ndq::coding::arithmetic::{self, AdaptiveModel};
use ndq::coding::{huffman, pack, BitReader, BitWriter, DECODE_CHUNK};
use ndq::prng::{DitherStream, Xoshiro256};
use ndq::stats::bench::Bench;

/// Drain `n` symbols through the chunked unpacker kernel, the way the
/// quantizer decode loops do ([`DECODE_CHUNK`] symbols per dispatch).
fn drain_chunked(src: &mut pack::SymbolUnpacker<'_, '_>, n: usize) -> u32 {
    let mut chunk = [0u32; DECODE_CHUNK];
    let mut acc = 0u32;
    let mut left = n;
    while left > 0 {
        let take = left.min(DECODE_CHUNK);
        src.fill_symbols(&mut chunk[..take]).unwrap();
        acc = acc.wrapping_add(chunk[take - 1]);
        left -= take;
    }
    acc
}

/// The pre-Fenwick `AdaptiveModel::range`/`find`: O(alphabet) linear scans
/// per symbol. Kept here (bench-only) as the baseline the tree replaced.
struct LinearModel {
    freq: Vec<u64>,
    total: u64,
}

impl LinearModel {
    fn new(alphabet: usize) -> Self {
        Self {
            freq: vec![1; alphabet],
            total: alphabet as u64,
        }
    }

    fn range(&self, s: usize) -> (u64, u64, u64) {
        let mut lo = 0u64;
        for &f in &self.freq[..s] {
            lo += f;
        }
        (lo, lo + self.freq[s], self.total)
    }

    fn find(&self, target: u64) -> (usize, u64, u64) {
        let mut lo = 0u64;
        for (s, &f) in self.freq.iter().enumerate() {
            if target < lo + f {
                return (s, lo, lo + f);
            }
            lo += f;
        }
        unreachable!()
    }

    fn update(&mut self, s: usize) {
        self.freq[s] += 32;
        self.total += 32;
        if self.total > (1 << 16) {
            self.total = 0;
            for f in &mut self.freq {
                *f = (*f >> 1).max(1);
                self.total += *f;
            }
        }
    }
}

fn main() -> ndq::Result<()> {
    let mut b = Bench::new();
    let n = 266_610usize;
    let mut rng = Xoshiro256::new(2);

    // gradient-index-like ternary stream, peaked at 0
    let symbols: Vec<u32> = (0..n)
        .map(|_| {
            let r = rng.next_f32();
            if r < 0.75 {
                1
            } else if r < 0.88 {
                0
            } else {
                2
            }
        })
        .collect();

    let r = b.run("pack_base3/266610", || {
        let mut w = BitWriter::new();
        pack::pack_base_k(&symbols, 3, &mut w);
        w
    });
    println!("    -> {:.1} M sym/s", r.throughput(n as f64) / 1e6);

    let mut w = BitWriter::new();
    pack::pack_base_k(&symbols, 3, &mut w);
    let packed = w.into_bytes();
    let r_scalar = b.run("unpack_base3/266610", || {
        let mut rd = BitReader::new(&packed);
        pack::unpack_base_k(&mut rd, 3, n).unwrap()
    });
    println!("    -> {:.1} M sym/s", r_scalar.throughput(n as f64) / 1e6);

    // monomorphized K3 kernel vs the per-symbol interpreter above — the
    // specialized decode path the quantizers dispatch to per RoundSpec
    let r = b.run("unpack_base3_chunked/266610", || {
        let mut rd = BitReader::new(&packed);
        let mut src = pack::SymbolUnpacker::new(&mut rd, 3, n);
        drain_chunked(&mut src, n)
    });
    println!(
        "    -> {:.1} M sym/s ({:.1}x vs per-symbol)",
        r.throughput(n as f64) / 1e6,
        r_scalar.median_ns / r.median_ns
    );

    // pow2 shift/mask lane: k = 16 exercises the other monomorphized family
    let symbols16: Vec<u32> = (0..n).map(|_| rng.next_below(16)).collect();
    let r = b.run("pack_base16/266610", || {
        let mut w = BitWriter::new();
        pack::pack_base_k(&symbols16, 16, &mut w);
        w
    });
    println!("    -> {:.1} M sym/s", r.throughput(n as f64) / 1e6);
    let mut w16 = BitWriter::new();
    pack::pack_base_k(&symbols16, 16, &mut w16);
    let packed16 = w16.into_bytes();
    let r16_scalar = b.run("unpack_base16/266610", || {
        let mut rd = BitReader::new(&packed16);
        pack::unpack_base_k(&mut rd, 16, n).unwrap()
    });
    println!("    -> {:.1} M sym/s", r16_scalar.throughput(n as f64) / 1e6);
    let r = b.run("unpack_base16_chunked/266610", || {
        let mut rd = BitReader::new(&packed16);
        let mut src = pack::SymbolUnpacker::new(&mut rd, 16, n);
        drain_chunked(&mut src, n)
    });
    println!(
        "    -> {:.1} M sym/s ({:.1}x vs per-symbol)",
        r.throughput(n as f64) / 1e6,
        r16_scalar.median_ns / r.median_ns
    );

    let r = b.run("aac_encode/266610", || {
        let mut w = BitWriter::new();
        arithmetic::encode(&symbols, 3, &mut w);
        w
    });
    println!("    -> {:.1} M sym/s", r.throughput(n as f64) / 1e6);

    let mut w = BitWriter::new();
    arithmetic::encode(&symbols, 3, &mut w);
    let coded = w.into_bytes();
    let r = b.run("aac_decode/266610", || {
        let mut rd = BitReader::new(&coded);
        arithmetic::decode(&mut rd, 3, n).unwrap()
    });
    println!("    -> {:.1} M sym/s", r.throughput(n as f64) / 1e6);

    // Huffman on the same stream: the third on-wire codec
    let r = b.run("huffman_encode/266610", || {
        let mut w = BitWriter::new();
        huffman::encode(&symbols, 3, &mut w);
        w
    });
    println!("    -> {:.1} M sym/s", r.throughput(n as f64) / 1e6);

    let mut w = BitWriter::new();
    huffman::encode(&symbols, 3, &mut w);
    let hcoded = w.into_bytes();
    let r_hwalk = b.run("huffman_decode/266610", || {
        let mut rd = BitReader::new(&hcoded);
        huffman::decode(&mut rd, 3, n).unwrap()
    });
    println!("    -> {:.1} M sym/s", r_hwalk.throughput(n as f64) / 1e6);

    // table-driven Huffman decode (TABLE_BITS-wide LUT) vs the per-bit
    // canonical walk above, chunked the way the quantizer decodes run
    let r = b.run("huffman_decode_lut/266610", || {
        let mut rd = BitReader::new(&hcoded);
        let mut src = huffman::HuffmanSource::new(&mut rd, 3, n).unwrap();
        let mut chunk = [0u32; DECODE_CHUNK];
        let mut acc = 0u32;
        let mut left = n;
        while left > 0 {
            let take = left.min(DECODE_CHUNK);
            src.fill_symbols(&mut chunk[..take]).unwrap();
            acc = acc.wrapping_add(chunk[take - 1]);
            left -= take;
        }
        acc
    });
    println!(
        "    -> {:.1} M sym/s ({:.1}x vs per-bit walk)",
        r.throughput(n as f64) / 1e6,
        r_hwalk.median_ns / r.median_ns
    );

    // fast encode (precomputed bit-reversed codewords through push_bits)
    // vs the per-bit emit oracle it replaced
    let signed: Vec<i32> = symbols.iter().map(|&s| s as i32 - 1).collect();
    let r_hegen = b.run("huffman_encode_generic/266610", || {
        let mut w = BitWriter::new();
        huffman::encode_signed_generic(&signed, 1, &mut w);
        w
    });
    println!("    -> {:.1} M sym/s", r_hegen.throughput(n as f64) / 1e6);
    let r = b.run("huffman_encode_fast/266610", || {
        let mut w = BitWriter::new();
        huffman::encode_signed(&signed, 1, &mut w);
        w
    });
    println!(
        "    -> {:.1} M sym/s ({:.1}x vs per-bit emit)",
        r.throughput(n as f64) / 1e6,
        r_hegen.median_ns / r.median_ns
    );

    // adaptive-model cumulative counts at the 4096-symbol ceiling: the
    // Fenwick tree vs the old per-symbol linear scan it replaced (the win
    // that makes large-alphabet aac lanes affordable)
    let k = 4096usize;
    let lookups = 30_000usize;
    let big: Vec<u32> = (0..lookups).map(|_| rng.next_below(k as u32)).collect();
    let r_lin = b.run("aac_model_linear/k4096", || {
        let mut model = LinearModel::new(k);
        let mut acc = 0u64;
        for &s in &big {
            let (lo, hi, total) = model.range(s as usize);
            let (f, _, _) = model.find((lo + hi) / 2 % total);
            acc = acc.wrapping_add(f as u64);
            model.update(s as usize);
        }
        acc
    });
    println!("    -> {:.2} M lookups/s", r_lin.throughput(lookups as f64) / 1e6);
    let r_fen = b.run("aac_model_fenwick/k4096", || {
        let mut model = AdaptiveModel::new(k);
        let mut acc = 0u64;
        for &s in &big {
            let (lo, hi, total) = model.range(s as usize);
            let (f, _, _) = model.find((lo + hi) / 2 % total);
            acc = acc.wrapping_add(f as u64);
            model.update(s as usize);
        }
        acc
    });
    println!(
        "    -> {:.2} M lookups/s ({:.1}x vs linear scan)",
        r_fen.throughput(lookups as f64) / 1e6,
        r_lin.median_ns / r_fen.median_ns
    );

    // end-to-end aac at the large alphabet (dominated by model queries)
    let big_n = 30_000usize;
    let r = b.run("aac_encode/k4096/30000", || {
        let mut w = BitWriter::new();
        arithmetic::encode(&big, k, &mut w);
        w
    });
    println!("    -> {:.2} M sym/s", r.throughput(big_n as f64) / 1e6);

    // dither generation (Philox fill)
    let mut buf = vec![0f32; n];
    let stream = DitherStream::new(0, 0);
    let mut round = 0u64;
    let r = b.run("philox_fill_dither/266610", || {
        round += 1;
        stream.round(round).fill_dither(0.5, &mut buf);
        buf[0]
    });
    println!("    -> {:.1} M dithers/s", r.throughput(n as f64) / 1e6);

    b.save("perf_coding")?;
    Ok(())
}
