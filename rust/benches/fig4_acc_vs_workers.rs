//! Figure 4: final accuracy vs number of workers for FC-300-100 and LeNet
//! (SGD, total batch 256 split evenly).
//!
//! Paper shape: DQSG tracks the baseline across worker counts; QSGD/
//! TernGrad slightly below; One-Bit clearly below; gaps shrink as P grows
//! (averaging washes out quantization noise).

mod common;

use ndq::config::TrainConfig;
use ndq::quant::Scheme;
use ndq::stats::bench::{print_table_header, print_table_row};
use ndq::train::Trainer;
use ndq::util::json::{self, Json};

fn main() -> ndq::Result<()> {
    if common::skip_or_panic() {
        return Ok(());
    }
    let schemes = [
        ("Baseline", Scheme::Baseline),
        ("DQSG", Scheme::Dithered { delta: 1.0 }),
        ("QSGD", Scheme::Qsgd { m: 1 }),
        ("One-Bit", Scheme::OneBit),
    ];
    // (model, worker counts, rounds) — LeNet is ~10x slower per round
    let plans: &[(&str, &[usize], usize)] = &[
        ("fc300", &[1, 2, 4, 8, 16, 32], common::rounds(150)),
        ("lenet", &[2, 8], common::rounds(40)),
    ];
    let mut out_rows = Vec::new();
    for (model, worker_counts, rounds) in plans {
        print_table_header(
            &format!("Fig. 4 — {model}: final accuracy vs workers ({rounds} rounds)"),
            &worker_counts
                .iter()
                .map(|p| format!("P={p}"))
                .collect::<Vec<_>>()
                .iter()
                .map(|s| s.as_str())
                .collect::<Vec<_>>(),
        );
        let mut per_scheme = Vec::new();
        for (name, scheme) in &schemes {
            let mut accs = Vec::new();
            for &p in *worker_counts {
                let cfg = TrainConfig {
                    model: model.to_string(),
                    workers: p,
                    scheme: *scheme,
                    rounds: *rounds,
                    eval_every: 0,
                    eval_examples: 512,
                    ..TrainConfig::default()
                };
                let report = Trainer::new(cfg)?.run()?;
                accs.push(report.final_accuracy);
            }
            print_table_row(name, &accs);
            per_scheme.push((*name, accs));
        }
        // shape: at every P, DQSG within a few points of baseline and above
        // One-Bit on average
        if common::fast() {
            eprintln!("(fast mode: skipping shape assertions — accuracy is noise at this budget)");
        } else {
        let base = &per_scheme[0].1;
        let dqsg = &per_scheme[1].1;
        let onebit = &per_scheme[3].1;
        let mean = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        assert!(
            (mean(base) - mean(dqsg)).abs() < 0.12,
            "{model}: DQSG should track baseline ({:.3} vs {:.3})",
            mean(dqsg),
            mean(base)
        );
        assert!(
            mean(dqsg) > mean(onebit),
            "{model}: DQSG must beat One-Bit on average"
        );
        }
        for (name, accs) in per_scheme {
            out_rows.push(json::obj(vec![
                ("model", json::s(model)),
                ("scheme", json::s(name)),
                (
                    "workers",
                    json::f32s(&worker_counts.iter().map(|&p| p as f32).collect::<Vec<_>>()),
                ),
                ("accuracy", json::f32s(&accs.iter().map(|&a| a as f32).collect::<Vec<_>>())),
            ]));
        }
    }
    println!("\nshape checks passed: DQSG ~ baseline > One-Bit across worker counts");
    common::save_json("fig4.json", Json::Arr(out_rows));
    Ok(())
}
