//! Table 1: raw (uncompressed) communication bits per worker per iteration,
//! for FC-300-100 / LeNet / CifarNet across Baseline, DQSGD, QSGD,
//! TernGrad, One-Bit.
//!
//! We encode a *real* gradient of each model (computed through the AOT
//! artifact) and report the exact wire size of the message. Paper numbers
//! are printed beside ours: the paper counts indices at the ideal
//! information rate (log2 of the alphabet), our packer adds <1% amortized
//! overhead — the bench prints both so the comparison is explicit.

mod common;

use ndq::prng::DitherStream;
use ndq::quant::{GradQuantizer, Scheme};
use ndq::stats::bench::{print_table_header, print_table_row};
use ndq::util::json::{self, Json};

// Table 1 of the paper, Kbits / worker / iteration.
const PAPER: &[(&str, [f64; 5])] = &[
    ("fc300", [8531.5, 422.8, 422.8, 426.2, 342.6]),
    ("lenet", [53227.8, 2636.7, 2636.7, 2641.2, 1897.8]),
    ("cifarnet", [34185.5, 1690.0, 1690.0, 1692.0, 1251.0]),
];

fn main() -> ndq::Result<()> {
    if common::skip_or_panic() {
        return Ok(());
    }
    let schemes = [
        ("Baseline", Scheme::Baseline),
        ("DQSGD", Scheme::Dithered { delta: 1.0 }),
        ("QSGD", Scheme::Qsgd { m: 1 }),
        ("TernGrad", Scheme::Terngrad),
        ("One-Bit", Scheme::OneBit),
    ];

    let mut rows = Vec::new();
    print_table_header(
        "Table 1 — raw Kbits per worker per iteration (ours / paper)",
        &["Baseline", "DQSGD", "QSGD", "TernGrad", "One-Bit"],
    );
    for (model, paper_row) in PAPER {
        let grad = common::real_gradient(model)?;
        let mut ours = Vec::new();
        for (_, scheme) in &schemes {
            let mut q = scheme.build();
            let stream = DitherStream::new(1, 0);
            let msg = q.encode(&grad, &mut stream.round(0));
            ours.push(msg.raw_bits() as f64 / 1000.0);
        }
        print_table_row(&format!("{model} (ours)"), &ours);
        print_table_row(&format!("{model} (paper)"), paper_row);
        // shape checks (hard assertions — this bench IS the reproduction)
        assert!((ours[1] - ours[2]).abs() < 0.5, "DQSGD != QSGD raw bits");
        assert!(ours[4] < ours[1], "One-Bit must use fewer raw bits");
        assert!(ours[0] / ours[1] > 15.0, "DQSGD must cut baseline ~20x");
        for (i, (o, p)) in ours.iter().zip(paper_row).enumerate() {
            let rel = (o - p) / p;
            assert!(
                rel.abs() < 0.35,
                "{model} scheme {i}: ours {o:.1} vs paper {p:.1}"
            );
        }
        rows.push(json::obj(vec![
            ("model", json::s(model)),
            (
                "ours_kbits",
                json::f32s(&ours.iter().map(|&x| x as f32).collect::<Vec<_>>()),
            ),
            (
                "paper_kbits",
                json::f32s(&paper_row.iter().map(|&x| x as f32).collect::<Vec<_>>()),
            ),
        ]));
    }
    println!("\nshape checks passed: DQSGD == QSGD, One-Bit < ternary raw, ~20x baseline cut");
    common::save_json("table1.json", Json::Arr(rows));
    Ok(())
}
