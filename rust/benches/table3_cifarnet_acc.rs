//! Table 3: CifarNet accuracy with Adam for 4 and 8 workers across schemes.
//!
//! Paper: 50 epochs on CIFAR-10 (Baseline 68.2, DQSG 65.6/64.1, QSG
//! 64.7/64.1, TernGrad 64.7/64, One-Bit 49.6/47.8). Our substrate is
//! synth-CIFAR on a 1-core CPU testbed, so the default budget is a fixed
//! round count (paper-shape, not paper-absolute); set NDQ_TABLE3_ROUNDS to
//! go longer. Shape under test: Baseline >= DQSG ~ QSG ~ TernGrad >>
//! One-Bit, and the quantized-vs-baseline gap grows slightly from 4 to 8
//! workers for One-Bit.

mod common;

use ndq::config::{OptKind, TrainConfig};
use ndq::quant::Scheme;
use ndq::stats::bench::{print_table_header, print_table_row};
use ndq::train::Trainer;
use ndq::util::json::{self, Json};

const PAPER: &[(usize, [f64; 5])] = &[
    (4, [68.2, 65.6, 64.7, 64.7, 49.6]),
    (8, [68.2, 64.1, 64.1, 64.0, 47.8]),
];

fn main() -> ndq::Result<()> {
    if common::skip_or_panic() {
        return Ok(());
    }
    let rounds = std::env::var("NDQ_TABLE3_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(common::rounds(120));
    let schemes = [
        ("Baseline", Scheme::Baseline),
        ("DQSG", Scheme::Dithered { delta: 0.5 }),
        ("QSG", Scheme::Qsgd { m: 2 }),
        ("TernGrad", Scheme::Terngrad),
        ("One-Bit", Scheme::OneBit),
    ];
    print_table_header(
        &format!("Table 3 — CifarNet accuracy (%) after {rounds} rounds, Adam (ours / paper@50ep)"),
        &["Baseline", "DQSG", "QSG", "TernGrad", "One-Bit"],
    );
    let mut rows = Vec::new();
    for (workers, paper_row) in PAPER {
        let mut ours = Vec::new();
        for (_, scheme) in &schemes {
            let cfg = TrainConfig {
                model: "cifarnet".into(),
                workers: *workers,
                scheme: *scheme,
                opt: OptKind::Adam,
                lr: 0.001,
                rounds,
                eval_every: 0,
                eval_examples: 512,
                ..TrainConfig::default()
            };
            let report = Trainer::new(cfg)?.run()?;
            ours.push(report.final_accuracy * 100.0);
        }
        print_table_row(&format!("{workers}w (ours)"), &ours);
        print_table_row(&format!("{workers}w (paper)"), paper_row);
        // shape: DQSG close to baseline, One-Bit clearly worse
        if common::fast() {
            eprintln!("(fast mode: skipping shape assertions)");
        } else {
        assert!(
            ours[1] > ours[4],
            "{workers} workers: DQSG {:.1} must beat One-Bit {:.1}",
            ours[1],
            ours[4]
        );
        assert!(
            (ours[0] - ours[1]).abs() < 15.0,
            "{workers} workers: DQSG should track baseline"
        );
        }
        rows.push(json::obj(vec![
            ("workers", json::num(*workers as f64)),
            ("rounds", json::num(rounds as f64)),
            ("ours_acc", json::f32s(&ours.iter().map(|&x| x as f32).collect::<Vec<_>>())),
            (
                "paper_acc",
                json::f32s(&paper_row.iter().map(|&x| x as f32).collect::<Vec<_>>()),
            ),
        ]));
    }
    println!("\nshape checks passed: baseline ~ DQSG ~ QSG ~ TernGrad >> One-Bit");
    common::save_json("table3.json", Json::Arr(rows));
    Ok(())
}
