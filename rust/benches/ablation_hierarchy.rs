//! Extension ablation (paper conclusion: "can be easily extended to
//! hierarchical distributed structures"): two-tier nested aggregation on
//! real gradients — bit cost per tier vs a flat all-DQSG deployment, and
//! aggregate fidelity vs the true mean, across topology shapes.

mod common;

use ndq::prng::Xoshiro256;
use ndq::stats::bench::{print_table_header, print_table_row};
use ndq::train::hierarchy::{aggregate_round, true_mean, Hierarchy};
use ndq::util::json::{self, Json};

fn main() -> ndq::Result<()> {
    if common::skip_or_panic() {
        return Ok(());
    }
    // worker gradients: one real model gradient + small per-worker noise
    // (the correlation structure Alg. 2 exploits, measured not assumed)
    let base = common::real_gradient("fc300")?;
    let n = base.len();
    print_table_header(
        "Hierarchical NDQSG — bits per tier vs flat DQSG (real fc300 gradient)",
        &["leaf Kbit", "root Kbit", "flat Kbit", "saving", "rmse"],
    );
    let mut rows = Vec::new();
    for (groups, per_group) in [(2usize, 4usize), (4, 4), (4, 8), (8, 4)] {
        let mut rng = Xoshiro256::new((groups * 100 + per_group) as u64);
        let sigma = 0.02 * ndq::tensor::linf_norm(&base);
        let grads: Vec<Vec<Vec<f32>>> = (0..groups)
            .map(|_| {
                (0..per_group)
                    .map(|_| {
                        base.iter()
                            .map(|&b| b + sigma * rng.next_normal())
                            .collect()
                    })
                    .collect()
            })
            .collect();
        let h = Hierarchy::paper_default(groups, per_group);
        let round = aggregate_round(&h, &grads, 11, 0)?;
        let want = true_mean(&grads);
        let rmse = (ndq::tensor::sq_dist(&round.average, &want) / n as f64).sqrt();
        let saving = 1.0 - round.leaf_bits as f64 / round.flat_dqsg_bits as f64;
        print_table_row(
            &format!("{groups}x{per_group}"),
            &[
                round.leaf_bits as f64 / 1000.0,
                round.root_bits as f64 / 1000.0,
                round.flat_dqsg_bits as f64 / 1000.0,
                saving,
                rmse,
            ],
        );
        assert!(saving > 0.2, "{groups}x{per_group}: saving {saving}");
        // fidelity: rmse is dominated by the fine-step quantization noise,
        // kappa * D1 / sqrt(12) reduced by averaging — allow 2x that
        let kappa = ndq::tensor::linf_norm(&base) as f64;
        let noise_floor = kappa / 3.0 / 12f64.sqrt();
        assert!(rmse < 2.0 * noise_floor, "rmse {rmse} vs floor {noise_floor}");
        rows.push(json::obj(vec![
            ("groups", json::num(groups as f64)),
            ("per_group", json::num(per_group as f64)),
            ("leaf_bits", json::num(round.leaf_bits as f64)),
            ("root_bits", json::num(round.root_bits as f64)),
            ("flat_bits", json::num(round.flat_dqsg_bits as f64)),
            ("rmse", json::num(rmse)),
        ]));
    }
    println!("\nshape check passed: nested tiers save >20% leaf bits at matched fidelity");
    common::save_json("ablation_hierarchy.json", Json::Arr(rows));
    Ok(())
}
