//! Figure 6: NDQSG vs DQSG vs baseline learning curves at 8 workers, plus
//! the §4 communication claim: DQSG at M = 2 (Delta = 1/2, 5 symbols) needs
//! 619.2 Kbit/worker on FC-300-100 while NDQSG's nested pair (Delta1 = 1/3,
//! Delta2 = 1 -> ternary symbols) needs 422.8 Kbit — >30% fewer bits at the
//! same quantization variance (Thm. 6).

mod common;

use ndq::config::TrainConfig;
use ndq::quant::Scheme;
use ndq::train::Trainer;
use ndq::util::json::{self, Json};

fn main() -> ndq::Result<()> {
    if common::skip_or_panic() {
        return Ok(());
    }
    let rounds = common::rounds(150);
    let eval_every = (rounds / 8).max(1);

    let runs: Vec<(&str, Scheme, Option<Scheme>)> = vec![
        ("Baseline", Scheme::Baseline, None),
        ("DQSG(M=2)", Scheme::Dithered { delta: 0.5 }, None),
        (
            "NDQSG",
            Scheme::Dithered { delta: 0.5 },
            Some(Scheme::Nested {
                d1: 1.0 / 3.0,
                ratio: 3,
                alpha: 1.0,
            }),
        ),
    ];

    let mut out = Vec::new();
    let mut reports = Vec::new();
    println!("=== Fig. 6 — FC-300-100, 8 workers, {rounds} rounds ===");
    for (name, s1, s2) in &runs {
        let cfg = TrainConfig {
            model: "fc300".into(),
            workers: 8,
            scheme: *s1,
            scheme_p2: *s2,
            rounds,
            eval_every,
            eval_examples: 1024,
            ..TrainConfig::default()
        };
        let report = Trainer::new(cfg)?.run()?;
        let curve: Vec<String> = report
            .history
            .iter()
            .map(|h| format!("{}:{:.3}", h.round, h.accuracy))
            .collect();
        println!("{name:<10} {}", curve.join("  "));
        out.push(json::obj(vec![
            ("run", json::s(name)),
            (
                "rounds",
                json::f32s(&report.history.iter().map(|h| h.round as f32).collect::<Vec<_>>()),
            ),
            (
                "accuracy",
                json::f32s(&report.history.iter().map(|h| h.accuracy as f32).collect::<Vec<_>>()),
            ),
            ("kbits_raw_per_msg", json::num(report.comm.kbits_per_msg_raw())),
        ]));
        reports.push((name.to_string(), report));
    }

    let dq = &reports[1].1;
    let nd = &reports[2].1;
    // Per-message bits: all-DQSG(M=2) workers send log2(5)-rate messages;
    // in the NDQSG run the P2 half send ternary. Compare mean uplink cost.
    let dq_bits = dq.comm.kbits_per_msg_raw();
    let nd_bits = nd.comm.kbits_per_msg_raw();
    let reduction = 100.0 * (1.0 - nd_bits / dq_bits);
    println!(
        "\nbits/msg: DQSG(M=2) {dq_bits:.1} Kbit vs NDQSG-mixed {nd_bits:.1} Kbit ({reduction:.0}% reduction)"
    );
    println!("paper: 619.2 -> 422.8 Kbit for the P2 workers (>30% reduction)");
    // per-P2-worker reduction: ternary vs 5-ary rate
    let p2_reduction = 100.0 * (1.0 - (3f64).log2() / (5f64).log2());
    println!("per-P2-worker rate reduction: {p2_reduction:.0}% (log2 3 vs log2 5)");

    // shape checks
    assert!(nd_bits < dq_bits, "NDQSG must reduce mean bits");
    let acc_gap = (nd.final_accuracy - dq.final_accuracy).abs();
    assert!(
        acc_gap < 0.08,
        "NDQSG accuracy must match DQSG (gap {acc_gap:.3})"
    );
    println!(
        "\nshape checks passed: NDQSG ~ DQSG accuracy (gap {acc_gap:.3}), fewer bits"
    );
    common::save_json("fig6.json", Json::Arr(out));
    Ok(())
}
