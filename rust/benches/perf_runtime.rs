//! §Perf: runtime dispatch comparison — the L1 quantize kernel executed via
//! PJRT versus the rust-native hot loop, plus per-model gradient-step cost
//! (the denominator of every "does quantization bottleneck the round?"
//! question) and the executable-cache hit check.

mod common;

use std::sync::Arc;

use ndq::data::{Batch, ImageDataset, ImageKind};
use ndq::prng::DitherStream;
use ndq::quant::{GradQuantizer, Scheme};
use ndq::runtime::{ComputeService, Manifest, RawArg};
use ndq::stats::bench::Bench;

fn main() -> ndq::Result<()> {
    if common::skip_or_panic() {
        return Ok(());
    }
    let mut b = Bench::new();
    let svc = ComputeService::start(std::path::Path::new("artifacts"))?;
    let h = svc.handle();
    let m = Manifest::load(std::path::Path::new("artifacts"))?;

    // -- gradient step per model (the round's compute cost) --
    for model in ["fc300", "lenet", "cifarnet"] {
        let params = Arc::new(m.init_params(model)?);
        let kind = ImageKind::for_model(model)?;
        let ds = ImageDataset::new(kind, 0);
        let bsz = 32;
        let mut batch = Batch::new(bsz, kind.feature_dim());
        ds.train_batch(0, 0, 1, bsz, &mut batch);
        b.run(&format!("grad_step/{model}/b32"), || {
            h.grad_image(model, &params, batch.x.clone(), batch.y.clone(), bsz)
                .unwrap()
        });
    }

    // -- PJRT-dispatched Pallas quantize kernel vs rust-native --
    let n = 266_610usize;
    let params = Arc::new(m.init_params("fc300")?);
    let grad = common::gradient_at(&h, "fc300", &params, 0)?;
    let mut u = vec![0f32; n];
    DitherStream::new(0, 0).round(0).fill_dither(0.5, &mut u);

    let r_pjrt = b.run("quantize/pjrt_kernel/266610", || {
        h.exec_raw(
            &format!("quantize_dq_{n}"),
            vec![
                RawArg::F32(grad.clone(), vec![n as i64]),
                RawArg::F32(u.clone(), vec![n as i64]),
            ],
        )
        .unwrap()
    });

    let mut q = Scheme::Dithered { delta: 1.0 }.build();
    let stream = DitherStream::new(0, 0);
    let r_rust = b.run("quantize/rust_native/266610", || {
        q.encode(&grad, &mut stream.round(0))
    });
    println!(
        "\nPJRT kernel vs rust-native encode: {:.2}x (note: rust-native also packs bits)",
        r_pjrt.median_ns / r_rust.median_ns
    );

    // -- executable cache: steady state must be all hits --
    let (compiles, executions) = h.stats()?;
    println!("compiles = {compiles}, executions = {executions}");
    assert!(
        executions > compiles * 3,
        "executable cache not amortizing: {compiles} compiles / {executions} execs"
    );

    b.save("perf_runtime")?;
    Ok(())
}
