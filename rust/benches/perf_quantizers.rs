//! §Perf: quantizer hot-path microbenchmarks — ns/element for encode and
//! decode at the paper's model sizes. The target: quantize+encode must be
//! a small fraction of the gradient-compute time, so L3 never bottlenecks
//! the round (see EXPERIMENTS.md §Perf for the compute-time comparison).

mod common;

use ndq::prng::{DitherStream, Xoshiro256};
use ndq::quant::{GradQuantizer, Scheme};
use ndq::stats::bench::Bench;

fn main() -> ndq::Result<()> {
    let mut b = Bench::new();
    let mut rng = Xoshiro256::new(1);
    for n in [266_610usize, 1_663_370] {
        let g: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.1).collect();
        println!("\n--- n = {n} ---");
        for scheme in [
            Scheme::Baseline,
            Scheme::Dithered { delta: 1.0 },
            Scheme::Dithered { delta: 0.5 },
            Scheme::DitheredPartitioned { delta: 1.0, k: 8 },
            Scheme::Qsgd { m: 1 },
            Scheme::Terngrad,
            Scheme::OneBit,
            Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
        ] {
            let mut q = scheme.build();
            let stream = DitherStream::new(0, 0);
            let mut round = 0u64;
            let label = format!("encode/{}/{n}", scheme.label());
            let r = b.run(&label, || {
                round += 1;
                q.encode(&g, &mut stream.round(round))
            });
            println!(
                "    -> {:.2} ns/elem, {:.1} M elem/s",
                r.median_ns / n as f64,
                r.throughput(n as f64) / 1e6
            );

            // decode (needs a message + side info for nested)
            let msg = q.encode(&g, &mut stream.round(0));
            let y: Vec<f32> = g.iter().map(|&x| x + 0.001).collect();
            let side = q.needs_side_info();
            let label = format!("decode/{}/{n}", scheme.label());
            let rd = b.run(&label, || {
                q.decode(
                    &msg,
                    &mut stream.round(0),
                    if side { Some(&y) } else { None },
                )
                .unwrap()
            });
            println!(
                "    -> {:.2} ns/elem decode",
                rd.median_ns / n as f64
            );
        }
    }
    b.save("perf_quantizers")?;
    Ok(())
}
