//! §Perf: quantizer hot-path microbenchmarks — ns/element for encode and
//! decode at the paper's model sizes. The target: quantize+encode must be
//! a small fraction of the gradient-compute time, so L3 never bottlenecks
//! the round (see EXPERIMENTS.md §Perf for the compute-time comparison).

mod common;

use ndq::prng::{DitherStream, Xoshiro256};
use ndq::quant::{GradQuantizer, KernelMode, PayloadCodec, Scheme};
use ndq::stats::bench::Bench;

fn main() -> ndq::Result<()> {
    let mut b = Bench::new();
    let mut rng = Xoshiro256::new(1);
    for n in [266_610usize, 1_663_370] {
        let g: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.1).collect();
        println!("\n--- n = {n} ---");
        for scheme in [
            Scheme::Baseline,
            Scheme::Dithered { delta: 1.0 },
            Scheme::Dithered { delta: 0.5 },
            Scheme::DitheredPartitioned { delta: 1.0, k: 8 },
            Scheme::Qsgd { m: 1 },
            Scheme::Terngrad,
            Scheme::OneBit,
            Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
        ] {
            let mut q = scheme.build();
            let stream = DitherStream::new(0, 0);
            let mut round = 0u64;
            let label = format!("encode/{}/{n}", scheme.label());
            let r = b.run(&label, || {
                round += 1;
                q.encode(&g, &mut stream.round(round))
            });
            println!(
                "    -> {:.2} ns/elem, {:.1} M elem/s",
                r.median_ns / n as f64,
                r.throughput(n as f64) / 1e6
            );

            // decode (needs a message + side info for nested)
            let msg = q.encode(&g, &mut stream.round(0));
            let y: Vec<f32> = g.iter().map(|&x| x + 0.001).collect();
            let side = q.needs_side_info();
            let label = format!("decode/{}/{n}", scheme.label());
            let rd = b.run(&label, || {
                q.decode(
                    &msg,
                    &mut stream.round(0),
                    if side { Some(&y) } else { None },
                )
                .unwrap()
            });
            println!(
                "    -> {:.2} ns/elem decode",
                rd.median_ns / n as f64
            );
        }
    }
    // generic vs monomorphized decode kernels on the same wire bytes: the
    // reconstruction is bit-identical either way (pinned by
    // tests/kernel_differential.rs); only the dispatch differs. The
    // specialized path is what Scheme::build resolves per RoundSpec.
    let n = 266_610usize;
    let g: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.1).collect();
    println!("\n--- kernel dispatch, n = {n} ---");
    for (scheme, codec) in [
        (Scheme::Dithered { delta: 1.0 }, PayloadCodec::Raw), // K3 kernel
        (Scheme::Dithered { delta: 1.0 }, PayloadCodec::Huffman), // decode LUT
        (Scheme::Dithered { delta: 1.0 / 7.0 }, PayloadCodec::Raw), // K15 kernel
        (
            Scheme::Nested { d1: 1.0 / 3.0, ratio: 3, alpha: 1.0 },
            PayloadCodec::Raw,
        ),
    ] {
        let mut enc = scheme.build();
        let stream = DitherStream::new(0, 0);
        let msg = enc.encode_coded(&g, &mut stream.round(0), codec);
        let y: Vec<f32> = g.iter().map(|&x| x + 0.001).collect();
        let side = enc.needs_side_info();
        let generic = scheme.build_with_mode(KernelMode::Generic);
        let specialized = scheme.build_with_mode(KernelMode::Specialized);
        let mut out = vec![0f32; n];
        let label = format!("decode_generic/{}/{}/{n}", scheme.label(), codec.label());
        let rg = b.run(&label, || {
            generic
                .decode_into(
                    &msg,
                    &mut stream.round(0),
                    if side { Some(&y) } else { None },
                    &mut out,
                )
                .unwrap();
            out[0]
        });
        println!("    -> {:.2} ns/elem decode (generic)", rg.median_ns / n as f64);
        let label = format!("decode_specialized/{}/{}/{n}", scheme.label(), codec.label());
        let rs = b.run(&label, || {
            specialized
                .decode_into(
                    &msg,
                    &mut stream.round(0),
                    if side { Some(&y) } else { None },
                    &mut out,
                )
                .unwrap();
            out[0]
        });
        println!(
            "    -> {:.2} ns/elem decode (specialized, {:.1}x vs generic)",
            rs.median_ns / n as f64,
            rg.median_ns / rs.median_ns
        );
    }

    b.save("perf_quantizers")?;
    Ok(())
}
