//! Extension ablation (paper conclusion: "applicable to the asynchronous
//! training as well"): bounded-staleness async DQSGD vs the synchronous
//! trainer at matched update budgets, sweeping the staleness bound.
//!
//! Shape under test: small staleness bounds track synchronous accuracy;
//! the quantizer keeps working unchanged because the counter-keyed dither
//! streams decode in any arrival order.

mod common;

use ndq::config::TrainConfig;
use ndq::quant::Scheme;
use ndq::stats::bench::{print_table_header, print_table_row};
use ndq::train::{AsyncTrainer, Trainer};
use ndq::util::json::{self, Json};

fn main() -> ndq::Result<()> {
    if common::skip_or_panic() {
        return Ok(());
    }
    let rounds = common::rounds(80);
    let base_cfg = TrainConfig {
        model: "fc300".into(),
        workers: 4,
        scheme: Scheme::Dithered { delta: 1.0 },
        rounds,
        eval_every: 0,
        eval_examples: 512,
        ..TrainConfig::default()
    };

    // synchronous reference
    let sync_report = Trainer::new(base_cfg.clone())?.run()?;
    print_table_header(
        &format!("Async DQSGD vs staleness bound (fc300, {rounds} rounds of work)"),
        &["bound", "final acc", "mean stale", "max stale"],
    );
    print_table_row(
        "sync",
        &[0.0, sync_report.final_accuracy, 0.0, 0.0],
    );

    let mut rows = vec![json::obj(vec![
        ("mode", json::s("sync")),
        ("accuracy", json::num(sync_report.final_accuracy)),
    ])];
    let mut accs = Vec::new();
    for bound in [1usize, 3, 8] {
        let mut t = AsyncTrainer::new(base_cfg.clone(), bound)?;
        let (report, stats) = t.run()?;
        print_table_row(
            &format!("s<={bound}"),
            &[
                bound as f64,
                report.final_accuracy,
                stats.mean_staleness,
                stats.max_staleness_seen as f64,
            ],
        );
        accs.push(report.final_accuracy);
        rows.push(json::obj(vec![
            ("mode", json::s(&format!("async_s{bound}"))),
            ("accuracy", json::num(report.final_accuracy)),
            ("mean_staleness", json::num(stats.mean_staleness)),
            ("max_staleness", json::num(stats.max_staleness_seen as f64)),
        ]));
    }
    // shape: bounded-staleness async stays in the sync ballpark
    if common::fast() {
        eprintln!("(fast mode: skipping shape assertions)");
    } else {
    for (i, acc) in accs.iter().enumerate() {
        assert!(
            sync_report.final_accuracy - acc < 0.25,
            "async run {i} collapsed: {acc} vs sync {}",
            sync_report.final_accuracy
        );
    }
    }
    println!("\nshape check passed: bounded-staleness async tracks synchronous accuracy");
    common::save_json("ablation_async.json", Json::Arr(rows));
    Ok(())
}
