//! Figure 5: convergence curves (accuracy vs training round) for CifarNet
//! with Adam, 4 and 8 workers: baseline vs DQSGD vs QSGD vs One-Bit.
//!
//! Paper shape: DQSGD converges at least as fast as the baseline (the
//! independent dither noise can even help — §4), QSGD close behind, One-Bit
//! visibly slower.

mod common;

use ndq::config::{OptKind, TrainConfig};
use ndq::quant::Scheme;
use ndq::train::Trainer;
use ndq::util::json::{self, Json};

fn main() -> ndq::Result<()> {
    if common::skip_or_panic() {
        return Ok(());
    }
    let rounds = common::rounds(100);
    let eval_every = (rounds / 8).max(1);
    let schemes = [
        ("Baseline", Scheme::Baseline),
        ("DQSGD", Scheme::Dithered { delta: 0.5 }),
        ("QSGD", Scheme::Qsgd { m: 2 }),
        ("One-Bit", Scheme::OneBit),
    ];
    let mut out = Vec::new();
    for workers in [4usize, 8] {
        println!("\n=== Fig. 5 — CifarNet Adam, {workers} workers ({rounds} rounds) ===");
        let mut finals = Vec::new();
        for (name, scheme) in &schemes {
            let cfg = TrainConfig {
                model: "cifarnet".into(),
                workers,
                scheme: *scheme,
                opt: OptKind::Adam,
                lr: 0.001,
                rounds,
                eval_every,
                eval_examples: 512,
                ..TrainConfig::default()
            };
            let report = Trainer::new(cfg)?.run()?;
            let curve: Vec<String> = report
                .history
                .iter()
                .map(|h| format!("{}:{:.3}", h.round, h.accuracy))
                .collect();
            println!("{name:<10} {}", curve.join("  "));
            finals.push(report.final_accuracy);
            out.push(json::obj(vec![
                ("workers", json::num(workers as f64)),
                ("scheme", json::s(name)),
                (
                    "rounds",
                    json::f32s(
                        &report
                            .history
                            .iter()
                            .map(|h| h.round as f32)
                            .collect::<Vec<_>>(),
                    ),
                ),
                (
                    "accuracy",
                    json::f32s(
                        &report
                            .history
                            .iter()
                            .map(|h| h.accuracy as f32)
                            .collect::<Vec<_>>(),
                    ),
                ),
            ]));
        }
        // shape: One-Bit trails the others at the end of the budget
        if common::fast() {
            eprintln!("(fast mode: skipping shape assertions)");
            continue;
        }
        assert!(
            finals[3] <= finals[0] + 0.02 && finals[3] <= finals[1] + 0.02,
            "One-Bit should converge slower (finals: {finals:?})"
        );
        assert!(
            (finals[1] - finals[0]).abs() < 0.15,
            "DQSGD should track baseline (finals: {finals:?})"
        );
    }
    println!("\nshape checks passed: DQSGD ~ baseline, One-Bit trails");
    common::save_json("fig5.json", Json::Arr(out));
    Ok(())
}
