//! Minimal offline stand-in for the `anyhow` crate.
//!
//! This workspace builds in environments with no crates.io access, so the
//! subset of `anyhow` it actually uses is vendored here:
//!
//! * [`Error`] — an opaque, `Send + Sync` error value built from a message
//!   or from any `std::error::Error` (the `?` conversion).
//! * [`Result`] — `Result<T, Error>` with the same default-parameter shape
//!   as the real crate.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the three construction macros.
//!
//! Unlike the real crate this shim keeps only the rendered message (no
//! source chain, no backtrace, no downcasting); nothing in this workspace
//! relies on those. Swapping the real `anyhow` back in is a one-line change
//! in the workspace `Cargo.toml`.

use std::fmt;

/// Opaque error type carrying a rendered message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// The `?`-conversion: any std error becomes an `Error`. `Error` itself does
// NOT implement `std::error::Error`, which is exactly what keeps this
// blanket impl coherent with `impl From<T> for T` (the same trick the real
// anyhow uses).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — `E` defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn conversions_and_macros() {
        fn parse(s: &str) -> crate::Result<u32> {
            let v: u32 = s.parse()?; // From<ParseIntError>
            crate::ensure!(v < 100, "too big: {v}");
            if v == 13 {
                crate::bail!("unlucky");
            }
            Ok(v)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
        assert_eq!(parse("400").unwrap_err().to_string(), "too big: 400");
        assert_eq!(parse("13").unwrap_err().to_string(), "unlucky");
        let e = crate::anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
        assert_eq!(format!("{e:?}"), "code 42");
    }

    #[test]
    fn bare_ensure_reports_condition() {
        fn check(x: i32) -> crate::Result<()> {
            crate::ensure!(x > 0);
            Ok(())
        }
        assert!(check(1).is_ok());
        let msg = check(-1).unwrap_err().to_string();
        assert!(msg.contains("x > 0"), "{msg}");
    }
}
